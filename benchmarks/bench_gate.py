"""Perf regression gate: every ``BENCH_*.json`` artifact is a ratchet.

The repo commits one JSON artifact per benchmark family (sweep, distributed,
churn, encounter, roofline). Before this gate they were snapshots — a future
PR could silently give back the 5.9x distributed scan or the 1.87x tiled
encounter win and nothing would notice. This module makes them a gated
trajectory:

**Schema validation** (fast, no benchmark execution — tier-1 runs it on
every push)::

    PYTHONPATH=src python -m benchmarks.bench_gate --check-committed

fails if any committed artifact is missing, malformed, names the wrong
``bench`` entry point, or is missing/mistyping a required key (including
its headline metric), so a hand-edited or truncated artifact cannot land.

**Regression gating** (the CI slow lane's produce-then-gate)::

    cp benchmarks/BENCH_*.json "$BASELINE"      # snapshot the committed ratchet
    PYTHONPATH=src python -m benchmarks.engine_micro --sweep --churn ...
    PYTHONPATH=src python -m benchmarks.bench_gate \
        --baseline "$BASELINE" --fresh benchmarks

compares each freshly produced artifact against the committed one on that
artifact's HEADLINE metric and fails on a regression beyond the threshold
(default ``10%``, ``--threshold 0.1``). Direction is per-artifact (speedups
must not fall, overheads must not rise); near-zero metrics (the churn
overhead) also carry an absolute slack so relative noise on tiny values
cannot flake the lane.

**The ratchet workflow**: when a PR makes a hot path faster, re-run the
producing benchmark and commit the fresh artifact — the gate then defends
the new number. Improvements always pass; only the committed file moves the
floor. Artifact schemas live in :data:`ARTIFACTS` below; see
``benchmarks/README.md`` for the human-readable version.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_THRESHOLD = 0.10
_HERE = os.path.dirname(os.path.abspath(__file__))


class GateSchemaError(ValueError):
    """An artifact violates its declared schema (wrong/missing/mistyped)."""


@dataclasses.dataclass(frozen=True)
class ArtifactSchema:
    """What the gate knows about one committed ``BENCH_*.json`` family."""
    bench: str                        # required value of the "bench" key
    required: Dict[str, type]         # top-level metric keys and their types
    headline: str                     # the ratcheted metric (in ``required``)
    higher_is_better: bool            # regression direction
    abs_slack: float = 0.0            # additive tolerance for near-zero metrics
    # additional ratcheted metrics: (key, higher_is_better, abs_slack) each,
    # gated with the same threshold as the headline — an artifact fails if
    # ANY gated metric regresses (the headline still fills the result row)
    extra_headlines: Tuple[Tuple[str, bool, float], ...] = ()

    def describe(self) -> str:
        arrow = "higher" if self.higher_is_better else "lower"
        extras = "".join(f" +{k}" for k, _, _ in self.extra_headlines)
        return f"headline={self.headline} ({arrow} is better){extras}"


ARTIFACTS: Dict[str, ArtifactSchema] = {
    "BENCH_sweep.json": ArtifactSchema(
        bench="engine_micro.run_sweep_bench",
        required={"sequential_retraced_s": float, "vmapped_cold_s": float,
                  "vmapped_warm_s": float, "speedup_vs_sequential": float,
                  "retraces_second_call": int},
        headline="speedup_vs_sequential", higher_is_better=True),
    "BENCH_distributed.json": ArtifactSchema(
        bench="engine_micro.run_distributed_bench",
        required={"per_step_loop_s": float, "scan_cold_s": float,
                  "scan_warm_s": float, "scan_warm_median_sketch_s": float,
                  "speedup_vs_per_step": float, "retraces_second_call": int,
                  "sweep_bitwise_equal": bool},
        headline="speedup_vs_per_step", higher_is_better=True),
    "BENCH_churn.json": ArtifactSchema(
        bench="engine_micro.run_churn_bench",
        required={"dense_warm_s": float, "masked_warm_s": float,
                  "overhead_pct": float, "retraces_masked_call": int,
                  "active_frac": float},
        # churn overhead hovers near zero: 10% of 6% is noise, so the gate
        # adds 2 percentage points of absolute slack on top
        headline="overhead_pct", higher_is_better=False, abs_slack=2.0),
    "BENCH_encounter.json": ArtifactSchema(
        bench="engine_micro.run_encounter_bench",
        required={"dense_warm_s": float, "tiled_warm_s": float,
                  "speedup_tiled_vs_dense": float, "host_gossip_warm_s": float,
                  "ring_gossip_warm_s": float, "ring_vs_host": float,
                  "ring_unpruned_warm_s": float,
                  "ring_vs_host_unpruned": float,
                  "hops_executed": int, "hops_pruned": int,
                  "payload_bytes_per_exchange": float,
                  "bucket_locality_fraction": float,
                  "area_bits_collision_rate": float,
                  "rebucket_every": int, "rebucket_threshold": float,
                  "rebucket_checks": int, "rebucket_swaps": int,
                  "prune_rate_q1_on": float, "prune_rate_q4_on": float,
                  "prune_rate_q1_off": float, "prune_rate_q4_off": float,
                  "rebucket_prune_retention": float},
        headline="speedup_tiled_vs_dense", higher_is_better=True,
        # the locality-aware ring ratchets alongside the tiled kernel: the
        # bench runs both the pruned and unpruned ring variants and this
        # gates the pruned ring's speedup over the single-host path; the
        # migration rows (run_migration_bench merges them in) ratchet the
        # re-bucketing retention ratio so hop-prune decay can't creep back
        extra_headlines=(("ring_vs_host", True, 0.0),
                         ("rebucket_prune_retention", True, 0.0))),
    "BENCH_scale.json": ArtifactSchema(
        bench="engine_micro.run_scale_bench",
        required={"curve": list, "max_m": int,
                  "steps_per_sec_at_max_m": float,
                  "parity_bitwise_all_m": bool,
                  "stream_schedule_bytes_at_max_m": int,
                  "materialized_schedule_bytes_at_max_m": int,
                  "schedule_bytes_ratio": float,
                  "peak_rss_stream_mb_at_max_m": float,
                  "peak_rss_materialized_mb_at_max_m": float,
                  "retraces_new_t": int,
                  "n_processes": int,
                  "rss_per_process_mb": list,
                  "parity_sha_ok": bool},
        # throughput of the streamed engine at the largest M on the curve
        # (the multi-process M=10^6 row when the cluster sweep ran); RSS
        # and schedule-bytes columns are telemetry for the O(chunk·M)
        # claim (asserted analytically in-bench, recorded here) and stay
        # pinned to the largest row with both engine modes.
        # parity_sha_ok pins bitwise agreement of the final weights
        # across cluster ranks; rss_per_process_mb is one entry per rank
        headline="steps_per_sec_at_max_m", higher_is_better=True),
    "BENCH_roofline.json": ArtifactSchema(
        bench="autotune.run_roofline",
        required={"roofline": list, "tuned": dict,
                  "tuned_speedup_vs_default": float},
        headline="tuned_speedup_vs_default", higher_is_better=True,
        # the tuned-vs-default ratio sits near 1.0 when the hand default is
        # already optimal; absolute slack keeps timing jitter out of the lane
        abs_slack=0.05),
}


def _typecheck(key: str, value, expected: type) -> None:
    if expected is float:
        ok = isinstance(value, (int, float)) and not isinstance(value, bool)
    elif expected is int:
        ok = isinstance(value, int) and not isinstance(value, bool)
    else:
        ok = isinstance(value, expected)
    if not ok:
        raise GateSchemaError(
            f"key {key!r}: expected {expected.__name__}, got "
            f"{type(value).__name__} ({value!r})")


def validate(name: str, payload) -> ArtifactSchema:
    """Validate one artifact payload against its declared schema.

    Raises :class:`GateSchemaError` (unknown artifact name, non-dict
    payload, wrong ``bench``, missing ``config``, missing or mistyped
    required key). Returns the schema on success.
    """
    schema = ARTIFACTS.get(name)
    if schema is None:
        raise GateSchemaError(
            f"unknown artifact {name!r}; the gate knows "
            f"{sorted(ARTIFACTS)}")
    if not isinstance(payload, dict):
        raise GateSchemaError(f"{name}: payload is {type(payload).__name__},"
                              f" not an object")
    if payload.get("bench") != schema.bench:
        raise GateSchemaError(
            f"{name}: bench={payload.get('bench')!r}, expected "
            f"{schema.bench!r}")
    if not isinstance(payload.get("config"), dict):
        raise GateSchemaError(f"{name}: missing config object")
    for key, expected in schema.required.items():
        if key not in payload:
            raise GateSchemaError(f"{name}: missing required key {key!r}")
        _typecheck(f"{name}:{key}", payload[key], expected)
    return schema


@dataclasses.dataclass
class GateResult:
    name: str
    ok: bool
    headline: str
    baseline: float
    fresh: float
    floor: float                       # the value fresh had to stay within
    reason: str

    def row(self) -> str:
        verdict = "PASS" if self.ok else "FAIL"
        return (f"{verdict}  {self.name:28s} {self.headline}: "
                f"{self.baseline:.4g} -> {self.fresh:.4g} "
                f"(limit {self.floor:.4g})  {self.reason}")


def gate_artifact(name: str, baseline: Dict, fresh: Dict,
                  threshold: float = DEFAULT_THRESHOLD) -> GateResult:
    """Compare a fresh artifact against the committed baseline.

    Both payloads are schema-validated first (raises
    :class:`GateSchemaError`). The fresh headline must not regress past
    ``threshold`` (relative) plus the artifact's absolute slack:

    - higher-is-better: ``fresh >= baseline * (1 - threshold) - abs_slack``
    - lower-is-better:  ``fresh <= baseline * (1 + threshold) + abs_slack``

    ``extra_headlines`` gate with the same rule; the result's numeric
    fields always report the primary headline, but ``ok`` requires every
    gated metric to hold and the reason names the first regressed one.
    """
    schema = validate(name, baseline)
    validate(name, fresh)

    def one(key, higher, slack):
        b = float(baseline[key])
        f = float(fresh[key])
        if higher:
            floor = b * (1.0 - threshold) - slack
            ok = f >= floor
            reason = ("improved or held" if f >= b else
                      f"dropped {(1 - f / b) * 100:.1f}%" if b else "dropped")
        else:
            floor = b * (1.0 + threshold) + slack
            ok = f <= floor
            reason = ("improved or held" if f <= b else
                      f"rose {(f - b):.4g}")
        return b, f, floor, ok, reason

    b, f, floor, ok, reason = one(schema.headline, schema.higher_is_better,
                                  schema.abs_slack)
    for key, higher, slack in schema.extra_headlines:
        _, xf, xfloor, xok, xreason = one(key, higher, slack)
        if ok and not xok:
            reason = f"{key} {xreason} ({xf:.4g}, limit {xfloor:.4g})"
        ok = ok and xok
    return GateResult(name=name, ok=ok, headline=schema.headline,
                      baseline=b, fresh=f, floor=floor, reason=reason)


def _load(path: str) -> Dict:
    try:
        with open(path) as fh:
            return json.load(fh)
    except FileNotFoundError:
        raise GateSchemaError(f"artifact missing: {path}")
    except ValueError as e:
        raise GateSchemaError(f"artifact unreadable: {path}: {e}")


def check_committed(directory: str = _HERE,
                    names: Optional[List[str]] = None) -> List[str]:
    """Schema-validate every committed artifact; returns validated names.

    This is the tier-1 step: no benchmark runs, just proof that what is
    committed parses and matches its schema (a malformed artifact would
    otherwise only surface in the weekly slow lane — or never).
    """
    out = []
    for name in sorted(names or ARTIFACTS):
        validate(name, _load(os.path.join(directory, name)))
        out.append(name)
    return out


def gate_all(baseline_dir: str, fresh_dir: str,
             threshold: float = DEFAULT_THRESHOLD,
             names: Optional[List[str]] = None) -> List[GateResult]:
    """Gate every (or the named) artifact pair; schema errors propagate."""
    results = []
    for name in sorted(names or ARTIFACTS):
        results.append(gate_artifact(
            name, _load(os.path.join(baseline_dir, name)),
            _load(os.path.join(fresh_dir, name)), threshold))
    return results


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="schema-validate and regression-gate BENCH_*.json "
                    "artifacts (see module docstring)")
    ap.add_argument("--check-committed", action="store_true",
                    help="schema-validate committed artifacts only "
                         "(no baseline comparison)")
    ap.add_argument("--dir", default=_HERE,
                    help="artifact directory for --check-committed")
    ap.add_argument("--baseline",
                    help="directory holding the committed (baseline) "
                         "artifacts")
    ap.add_argument("--fresh", default=_HERE,
                    help="directory holding freshly produced artifacts")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression tolerance on the headline "
                         "metric (default 0.10)")
    ap.add_argument("--artifact", action="append",
                    help="gate only this artifact (repeatable)")
    args = ap.parse_args(argv)

    try:
        if args.check_committed:
            for name in check_committed(args.dir, args.artifact):
                print(f"OK    {name:28s} "
                      f"{ARTIFACTS[name].describe()}")
            return 0
        if not args.baseline:
            ap.error("--baseline DIR is required unless --check-committed")
        results = gate_all(args.baseline, args.fresh, args.threshold,
                           args.artifact)
    except GateSchemaError as e:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 2
    for r in results:
        print(r.row())
    failed = [r for r in results if not r.ok]
    if failed:
        print(f"\n{len(failed)} artifact(s) regressed past "
              f"{args.threshold:.0%} — either fix the regression or "
              f"consciously re-commit the producing benchmark's fresh "
              f"artifact to move the ratchet", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
