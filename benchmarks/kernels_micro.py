"""Kernel microbenchmarks: us/call for each Pallas kernel's oracle + interpret
paths at several shapes (wall-clock is CPU; the numbers track relative block
configurations, not TPU latency)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mule_agg.ops import mule_agg
from repro.kernels.ssm_scan.ops import ssd_scan


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    k = jax.random.PRNGKey(0)
    # flash attention
    for (b, s, h, kv, d) in [(1, 512, 8, 2, 64), (1, 2048, 8, 2, 64)]:
        q = jax.random.normal(k, (b, s, h, d), jnp.float32)
        kk = jax.random.normal(k, (b, s, kv, d), jnp.float32)
        v = jax.random.normal(k, (b, s, kv, d), jnp.float32)
        us = _time(lambda: flash_attention(q, kk, v, backend="ref"))
        rows.append((f"flash.ref.s{s}", us, f"{4*s*s*h*d*b/1e9:.2f} GFLOP"))
    # ssd scan
    for (b, s, h, p, n) in [(1, 1024, 8, 64, 64)]:
        x = jax.random.normal(k, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(k, (b, s, h)))
        A = -jnp.exp(jax.random.normal(k, (h,)))
        B = jax.random.normal(k, (b, s, n))
        C = jax.random.normal(k, (b, s, n))
        us = _time(lambda: ssd_scan(x, dt, A, B, C, backend="ref")[0])
        rows.append((f"ssd.ref.s{s}", us, "chunk=64"))
    # mule_agg
    for (f, m, d) in [(8, 64, 1 << 18)]:
        assign = jax.random.uniform(k, (f, m))
        w = jax.random.normal(k, (m, d))
        us = _time(lambda: mule_agg(assign, w, backend="ref"))
        rows.append((f"mule_agg.ref.d{d}", us, f"{m*d*4/1e6:.0f}MB read"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def run_block_d_sweep():
    """mule_agg D-tile sweep — the MANUAL ancestor of the autotuner.

    Times the interpret-path kernel (wall-clock tracks relative block
    configurations on CPU, not TPU latency) at several (D, block_d) cells
    and prints the per-D argmin next to what ``pick_block_d`` currently
    returns. Re-tuning now goes through the tuning cache instead of a
    hand-edited table: ``python -m benchmarks.engine_micro --roofline``
    re-measures and rewrites ``benchmarks/BENCH_roofline.json``
    (``repro.launch.autotune``); this sweep survives as a quick
    cross-check that the cached selection still tracks measurements.
    """
    from repro.kernels.mule_agg.ops import pick_block_d
    k = jax.random.PRNGKey(0)
    f, m = 8, 64
    rows, best = [], {}
    for d in (1 << 12, 1 << 16, 1 << 18):
        assign = jax.random.uniform(k, (f, m))
        w = jax.random.normal(k, (m, d))
        for block_d in (256, 512, 1024, 2048, 4096):
            if block_d > max(128, d):
                continue
            us = _time(lambda: mule_agg(assign, w, block_d=block_d,
                                        interpret=True), n=3)
            rows.append((f"mule_agg.block.d{d}.b{block_d}", us,
                         f"{d // block_d} tiles"))
            if d not in best or us < best[d][1]:
                best[d] = (block_d, us)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    for d, (block_d, us) in sorted(best.items()):
        table = pick_block_d(d)
        print(f"mule_agg.block.best.d{d},{block_d},"
              f"table={table}{'' if table == block_d else ' (stale)'}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--block-d", action="store_true",
                    help="run only the mule_agg block_d sweep")
    args = ap.parse_args()
    if not args.block_d:
        run()
    run_block_d_sweep()
