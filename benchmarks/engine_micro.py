"""Scenario-engine microbenchmarks: driver overhead and sweep throughput.

``run()`` — per-step Python-loop driver vs the compiled ``lax.scan`` engine
on the same 500-step, 20-mule workload. The loop driver is the harness's
former hot path — one jitted ``population_step`` dispatch (plus batch
sampling and key splits) per time step; it survives as
``repro.scenarios.run_population_loop``, the parity reference. The engine
compiles the whole replay into one XLA program; the gap is almost pure
Python/jit dispatch overhead.

``run_sweep_bench()`` — the multi-seed sweep path this PR targets:
sequential ``run_population`` calls that retrace per call (the pre-cache
behavior, reproduced by clearing the jit cache between calls) vs ONE
vmapped compiled program over all seeds (``run_sweep``) hitting the cache.
Also asserts the jit cache's contract: a second same-shape
``run_population`` call performs zero retraces. Results land in
``BENCH_sweep.json`` so the perf trajectory is tracked PR over PR.

  PYTHONPATH=src python -m benchmarks.engine_micro            # both
  PYTHONPATH=src python -m benchmarks.engine_micro --sweep    # sweep only
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.mule_cnn import CNNConfig
from repro.core import PopulationConfig, init_population
from repro.models.cnn import cnn_forward, init_cnn, xent_loss
from repro.scenarios import (jit_cache_clear, jit_cache_stats,
                             run_population, run_population_loop, run_sweep,
                             stack_colocations, stack_trees,
                             walk_colocation)

_DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_sweep.json")


def _setup(n_fixed=8, n_mules=20, steps=500, batch=2, image=4, seed=0):
    # deliberately tiny CNN: the benchmark isolates driver overhead (Python
    # dispatch per step), so per-step FLOPs are kept well below dispatch cost
    mc = CNNConfig(image_size=image, conv_features=(2, 2), hidden=8,
                   n_classes=10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n_fixed, 64, image, image, 3))
    Y = jax.random.randint(key, (n_fixed, 64), 0, 10)

    def train_fn(params, b, k):
        xb, yb = b
        g = jax.grad(lambda p: xent_loss(cnn_forward(p, xb), yb))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(k, t):
        idx = jax.random.randint(k, (n_fixed, batch), 0, X.shape[1])
        return {"fixed": (jnp.take_along_axis(
                              X, idx[:, :, None, None, None], 1),
                          jnp.take_along_axis(Y, idx, 1)), "mule": None}

    pcfg = PopulationConfig(mode="fixed", n_fixed=n_fixed, n_mules=n_mules)
    pop = init_population(jax.random.PRNGKey(seed + 1),
                          lambda k: init_cnn(k, mc), pcfg)
    co = walk_colocation(seed, n_mules, steps)
    return pop, co, batch_fn, train_fn, pcfg


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def run(steps: int = 500, n_mules: int = 20):
    pop, co, batch_fn, train_fn, pcfg = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)

    # warm up both drivers (compile), then time one full replay each
    short = {k: (v[:3] if getattr(v, "ndim", 0) > 1 and v.shape[0] == steps
                 else v) for k, v in co.items()}
    _block(run_population_loop(pop, short, batch_fn, train_fn, pcfg, key)[0])
    t0 = time.perf_counter()
    out, _ = run_population_loop(pop, co, batch_fn, train_fn, pcfg, key)
    _block(out)
    loop_s = time.perf_counter() - t0

    # first call traces + compiles and fills the cache; the timed second
    # call is a pure cache hit measuring steady-state execution
    jit_cache_clear()
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    t0 = time.perf_counter()
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    scan_s = time.perf_counter() - t0
    assert jit_cache_stats()["traces"] == 1, "cached engine retraced"

    rows = [
        (f"engine.loop.T{steps}", loop_s * 1e6 / steps, "us/step"),
        (f"engine.scan.T{steps}", scan_s * 1e6 / steps, "us/step"),
        (f"engine.speedup.T{steps}", loop_s / scan_s, "x (loop/scan)"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    return rows


def run_sweep_bench(n_seeds: int = 8, steps: int = 300, n_mules: int = 20,
                    out_path: str = _DEFAULT_OUT):
    """8-seed mlmule sweep: sequential retraced vs one vmapped program."""
    setups = [_setup(n_mules=n_mules, steps=steps, seed=s)
              for s in range(n_seeds)]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    keys = [jax.random.PRNGKey(1000 + s) for s in range(n_seeds)]

    # -- sequential, retraced: the pre-cache engine paid one trace+compile
    # per (seed, method) cell; clearing the cache reproduces that cost
    t0 = time.perf_counter()
    for (pop, co, _, _, _), key in zip(setups, keys):
        jit_cache_clear()
        _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    seq_s = time.perf_counter() - t0

    # -- one vmapped compiled program over all seeds (cold: includes its
    # single trace+compile; warm: pure execution)
    states = stack_trees([s[0] for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    kstack = stack_trees(keys)
    jit_cache_clear()
    t0 = time.perf_counter()
    _block(run_sweep(states, cos, batch_fn, train_fn, pcfg, kstack)[0])
    vmap_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _block(run_sweep(states, cos, batch_fn, train_fn, pcfg, kstack)[0])
    vmap_warm_s = time.perf_counter() - t0

    # -- cache contract: a second same-shape run_population call must not
    # retrace (this is what made the sequential path slow to begin with)
    jit_cache_clear()
    pop, co = setups[0][0], setups[0][1]
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, keys[0])[0])
    before = jit_cache_stats()["traces"]
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, keys[1])[0])
    retraces = jit_cache_stats()["traces"] - before
    assert retraces == 0, "second same-shape run_population call retraced"

    speedup = seq_s / vmap_cold_s
    rows = [
        (f"sweep.sequential_retraced.S{n_seeds}.T{steps}", seq_s, "s total"),
        (f"sweep.vmapped_cold.S{n_seeds}.T{steps}", vmap_cold_s, "s total"),
        (f"sweep.vmapped_warm.S{n_seeds}.T{steps}", vmap_warm_s, "s total"),
        (f"sweep.speedup.S{n_seeds}.T{steps}", speedup,
         "x (sequential/vmapped-cold)"),
        (f"sweep.retraces_second_call", retraces, "count"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.3f},{derived}")

    payload = {
        "bench": "engine_micro.run_sweep_bench",
        "config": {"n_seeds": n_seeds, "steps": steps, "n_mules": n_mules,
                   "method": "mlmule", "backend": jax.default_backend()},
        "sequential_retraced_s": round(seq_s, 4),
        "vmapped_cold_s": round(vmap_cold_s, 4),
        "vmapped_warm_s": round(vmap_warm_s, 4),
        "speedup_vs_sequential": round(speedup, 2),
        "retraces_second_call": int(retraces),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="run only the sweep benchmark")
    ap.add_argument("--out", default=_DEFAULT_OUT)
    args = ap.parse_args()
    if not args.sweep:
        run()
    run_sweep_bench(out_path=args.out)
