"""Scenario-engine microbenchmark: per-step Python-loop driver vs the
compiled ``lax.scan`` engine on the same 500-step, 20-mule workload.

The loop driver is the harness's former hot path — one jitted
``population_step`` dispatch (plus batch sampling and key splits) per time
step. The engine compiles the whole replay into one XLA program; the gap is
almost pure Python/jit dispatch overhead, which is what every extra scenario
used to pay.

  PYTHONPATH=src python -m benchmarks.engine_micro
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mule_cnn import CNNConfig
from repro.core import PopulationConfig, init_population, population_step
from repro.models.cnn import cnn_forward, init_cnn, xent_loss
from repro.scenarios import run_population, walk_colocation


def _setup(n_fixed=8, n_mules=20, steps=500, batch=2, image=4):
    # deliberately tiny CNN: the benchmark isolates driver overhead (Python
    # dispatch per step), so per-step FLOPs are kept well below dispatch cost
    mc = CNNConfig(image_size=image, conv_features=(2, 2), hidden=8,
                   n_classes=10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n_fixed, 64, image, image, 3))
    Y = jax.random.randint(key, (n_fixed, 64), 0, 10)

    def train_fn(params, b, k):
        xb, yb = b
        g = jax.grad(lambda p: xent_loss(cnn_forward(p, xb), yb))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(k, t):
        idx = jax.random.randint(k, (n_fixed, batch), 0, X.shape[1])
        return {"fixed": (jnp.take_along_axis(
                              X, idx[:, :, None, None, None], 1),
                          jnp.take_along_axis(Y, idx, 1)), "mule": None}

    pcfg = PopulationConfig(mode="fixed", n_fixed=n_fixed, n_mules=n_mules)
    pop = init_population(jax.random.PRNGKey(1), lambda k: init_cnn(k, mc),
                          pcfg)
    co = walk_colocation(0, n_mules, steps)
    return pop, co, batch_fn, train_fn, pcfg


def _loop_driver(pop, co, batch_fn, train_fn, pcfg, key, steps):
    """The former harness pattern: one jitted dispatch per simulation step."""
    step = jax.jit(lambda s, i, b, k: population_step(
        s, i, b, train_fn, pcfg, k))
    fid_T = jnp.asarray(co["fixed_id"])
    exch_T = jnp.asarray(co["exchange"])
    for t in range(steps):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        pop = step(pop, {"fixed_id": fid_T[t], "exchange": exch_T[t]},
                   batch_fn(kb, t), ks)
    return pop


def run(steps: int = 500, n_mules: int = 20):
    pop, co, batch_fn, train_fn, pcfg, = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)

    # warm up both drivers (compile), then time one full replay each
    jax.block_until_ready(jax.tree.leaves(
        _loop_driver(pop, co, batch_fn, train_fn, pcfg, key, 3))[0])
    t0 = time.perf_counter()
    out = _loop_driver(pop, co, batch_fn, train_fn, pcfg, key, steps)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    loop_s = time.perf_counter() - t0

    # jit the whole replay so the timed call measures steady-state execution
    # (an eager lax.scan re-traces + recompiles on every invocation)
    engine = jax.jit(lambda pop, key: run_population(
        pop, co, batch_fn, train_fn, pcfg, key)[0])
    jax.block_until_ready(jax.tree.leaves(engine(pop, key))[0])
    t0 = time.perf_counter()
    jax.block_until_ready(jax.tree.leaves(engine(pop, key))[0])
    scan_s = time.perf_counter() - t0

    rows = [
        (f"engine.loop.T{steps}", loop_s * 1e6 / steps, "us/step"),
        (f"engine.scan.T{steps}", scan_s * 1e6 / steps, "us/step"),
        (f"engine.speedup.T{steps}", loop_s / scan_s, "x (loop/scan)"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
