"""Scenario-engine microbenchmarks: driver overhead and sweep throughput.

``run()`` — per-step Python-loop driver vs the compiled ``lax.scan`` engine
on the same 500-step, 20-mule workload. The loop driver is the harness's
former hot path — one jitted ``population_step`` dispatch (plus batch
sampling and key splits) per time step; it survives as
``repro.scenarios.run_population_loop``, the parity reference. The engine
compiles the whole replay into one XLA program; the gap is almost pure
Python/jit dispatch overhead.

``run_sweep_bench()`` — the multi-seed sweep path: sequential
``run_population`` calls that retrace per call (the pre-cache behavior,
reproduced by clearing the jit cache between calls) vs ONE vmapped
compiled program over all seeds (``run_sweep``) hitting the cache.
Also asserts the jit cache's contract: a second same-shape
``run_population`` call performs zero retraces. Results land in
``BENCH_sweep.json`` so the perf trajectory is tracked PR over PR.

``run_distributed_bench()`` — the mule-sharded path: the per-step
``run_population_distributed_loop`` driver (one jitted shard_map dispatch
per time step) vs the scan-based ``run_population_distributed`` (ONE
program, both freshness statistics), on a forced-host-device mesh. Also asserts zero
retraces on the warm call and that a vmapped distributed sweep is
bitwise-equal per lane to sequential distributed runs. Results land in
``BENCH_distributed.json``. Needs ≥ 8 devices: invoked without them, it
re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``run_churn_bench()`` — masked (churn) vs dense replay on the same schedule
shape: same compiled program (the mask is data — zero retraces), warm
overhead pinned <= 10% and tracked in ``BENCH_churn.json``.

``run_encounter_bench()`` — the peer-encounter mix: tiled
``encounter_mix`` kernel vs the retired dense path ([M, M] encounter
matrix + per-leaf ``masked_group_mean``; the tiled warm step must win),
plus ring-sharded vs single-host warm gossip replays on the forced
host-device mesh. Results land in ``BENCH_encounter.json``.

``run_migration_bench()`` — long-trace hop-prune decay: the exact host
mirror of the ring's pruning predicate on a persistent-relocation area
trace, with build-time bucketing only vs the drift-triggered mid-run
re-bucketing rule; asserts the re-bucketed prune rate holds into the
final quartile and merges retention telemetry into
``BENCH_encounter.json`` (run after ``--encounter``).

``run_donation_bench()`` — compile-time memory deltas of donating the
state pytree to the cached replay (``run_population(..., donate=True)``):
XLA aliases the state buffers into the outputs, so steady-state peak drops
by the full population size.

``run_roofline_bench()`` — the autotuner (``repro.launch.autotune``): the
scan-aware HLO analysis over the compiled engine step per (method × M ×
mesh) plus the measured kernel block-size sweeps, producing the tuning
cache ``BENCH_roofline.json`` that ``encounter_mix``/``mule_agg`` read
their tile sizes from. Needs ≥ 8 devices for the mesh rows; re-execs
itself with forced host devices like the distributed bench.

``run_scale_bench()`` — the population-scale curve (``--scale``): the
streamed engine (``run_population_streamed`` + the procedural
``commuter_stream`` generator, O(chunk·M) schedule memory) vs the classic
materialized ``[T, M]`` replay, per M up to 10^5, each (M, mode) in its own
subprocess so ``ru_maxrss`` is honest per-engine peak-RSS telemetry.
Cross-process sha256 digests of the final mule models pin streamed ==
materialized bitwise at every M, and a half-horizon replay pins the chunk
program as T-free (zero retraces). Results land in ``BENCH_scale.json``.

Every artifact is a gated ratchet: ``--gate-baseline DIR`` compares
whatever artifacts this invocation produced against the committed copies
in DIR via ``benchmarks.bench_gate`` and exits non-zero on a regression
(the CI slow lane snapshots the checkout's artifacts and passes that
directory here — see benchmarks/README.md).

  PYTHONPATH=src python -m benchmarks.engine_micro               # all
  PYTHONPATH=src python -m benchmarks.engine_micro --sweep       # sweep only
  PYTHONPATH=src python -m benchmarks.engine_micro --distributed # dist only
  PYTHONPATH=src python -m benchmarks.engine_micro --churn       # churn only
  PYTHONPATH=src python -m benchmarks.engine_micro --roofline    # autotune
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.mule_cnn import CNNConfig
from repro.core import PopulationConfig, init_population
from repro.core.freshness import FreshnessConfig
from repro.models.cnn import cnn_forward, init_cnn, xent_loss
from repro.scenarios import (jit_cache_clear, jit_cache_stats,
                             run_population, run_population_distributed,
                             run_population_loop, run_sweep,
                             run_sweep_distributed, stack_colocations,
                             stack_trees, walk_colocation)

_DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_sweep.json")
_DEFAULT_DIST_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_distributed.json")
_DEFAULT_CHURN_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "BENCH_churn.json")
_DEFAULT_ENC_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "BENCH_encounter.json")
_DEFAULT_ROOF_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_roofline.json")
_DEFAULT_SCALE_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "BENCH_scale.json")


def _setup(n_fixed=8, n_mules=20, steps=500, batch=2, image=4, seed=0):
    # deliberately tiny CNN: the benchmark isolates driver overhead (Python
    # dispatch per step), so per-step FLOPs are kept well below dispatch cost
    mc = CNNConfig(image_size=image, conv_features=(2, 2), hidden=8,
                   n_classes=10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n_fixed, 64, image, image, 3))
    Y = jax.random.randint(key, (n_fixed, 64), 0, 10)

    def train_fn(params, b, k):
        xb, yb = b
        g = jax.grad(lambda p: xent_loss(cnn_forward(p, xb), yb))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(k, t):
        idx = jax.random.randint(k, (n_fixed, batch), 0, X.shape[1])
        return {"fixed": (jnp.take_along_axis(
                              X, idx[:, :, None, None, None], 1),
                          jnp.take_along_axis(Y, idx, 1)), "mule": None}

    pcfg = PopulationConfig(mode="fixed", n_fixed=n_fixed, n_mules=n_mules)
    pop = init_population(jax.random.PRNGKey(seed + 1),
                          lambda k: init_cnn(k, mc), pcfg)
    co = walk_colocation(seed, n_mules, steps)
    return pop, co, batch_fn, train_fn, pcfg


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def run(steps: int = 500, n_mules: int = 20):
    pop, co, batch_fn, train_fn, pcfg = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)

    # warm up both drivers (compile), then time one full replay each
    short = {k: (v[:3] if getattr(v, "ndim", 0) > 1 and v.shape[0] == steps
                 else v) for k, v in co.items()}
    _block(run_population_loop(pop, short, batch_fn, train_fn, pcfg, key)[0])
    t0 = time.perf_counter()
    out, _ = run_population_loop(pop, co, batch_fn, train_fn, pcfg, key)
    _block(out)
    loop_s = time.perf_counter() - t0

    # first call traces + compiles and fills the cache; the timed second
    # call is a pure cache hit measuring steady-state execution
    jit_cache_clear()
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    t0 = time.perf_counter()
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    scan_s = time.perf_counter() - t0
    assert jit_cache_stats()["traces"] == 1, "cached engine retraced"

    rows = [
        (f"engine.loop.T{steps}", loop_s * 1e6 / steps, "us/step"),
        (f"engine.scan.T{steps}", scan_s * 1e6 / steps, "us/step"),
        (f"engine.speedup.T{steps}", loop_s / scan_s, "x (loop/scan)"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    return rows


def run_sweep_bench(n_seeds: int = 8, steps: int = 300, n_mules: int = 20,
                    out_path: str = _DEFAULT_OUT):
    """8-seed mlmule sweep: sequential retraced vs one vmapped program."""
    setups = [_setup(n_mules=n_mules, steps=steps, seed=s)
              for s in range(n_seeds)]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    keys = [jax.random.PRNGKey(1000 + s) for s in range(n_seeds)]

    # -- sequential, retraced: the pre-cache engine paid one trace+compile
    # per (seed, method) cell; clearing the cache reproduces that cost
    t0 = time.perf_counter()
    for (pop, co, _, _, _), key in zip(setups, keys):
        jit_cache_clear()
        _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    seq_s = time.perf_counter() - t0

    # -- one vmapped compiled program over all seeds (cold: includes its
    # single trace+compile; warm: pure execution)
    states = stack_trees([s[0] for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    kstack = stack_trees(keys)
    jit_cache_clear()
    t0 = time.perf_counter()
    _block(run_sweep(states, cos, batch_fn, train_fn, pcfg, kstack)[0])
    vmap_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    _block(run_sweep(states, cos, batch_fn, train_fn, pcfg, kstack)[0])
    vmap_warm_s = time.perf_counter() - t0

    # -- cache contract: a second same-shape run_population call must not
    # retrace (this is what made the sequential path slow to begin with)
    jit_cache_clear()
    pop, co = setups[0][0], setups[0][1]
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, keys[0])[0])
    before = jit_cache_stats()["traces"]
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, keys[1])[0])
    retraces = jit_cache_stats()["traces"] - before
    assert retraces == 0, "second same-shape run_population call retraced"

    speedup = seq_s / vmap_cold_s
    rows = [
        (f"sweep.sequential_retraced.S{n_seeds}.T{steps}", seq_s, "s total"),
        (f"sweep.vmapped_cold.S{n_seeds}.T{steps}", vmap_cold_s, "s total"),
        (f"sweep.vmapped_warm.S{n_seeds}.T{steps}", vmap_warm_s, "s total"),
        (f"sweep.speedup.S{n_seeds}.T{steps}", speedup,
         "x (sequential/vmapped-cold)"),
        (f"sweep.retraces_second_call", retraces, "count"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.3f},{derived}")

    payload = {
        "bench": "engine_micro.run_sweep_bench",
        "config": {"n_seeds": n_seeds, "steps": steps, "n_mules": n_mules,
                   "method": "mlmule", "backend": jax.default_backend()},
        "sequential_retraced_s": round(seq_s, 4),
        "vmapped_cold_s": round(vmap_cold_s, 4),
        "vmapped_warm_s": round(vmap_warm_s, 4),
        "speedup_vs_sequential": round(speedup, 2),
        "retraces_second_call": int(retraces),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


def run_donation_bench(steps: int = 300, n_mules: int = 20):
    """Compile-time memory effect of donating the replay's state buffers."""
    from repro.scenarios.engine import _colocation_tensors, get_compiled_replay

    pop, co, batch_fn, train_fn, pcfg = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)
    fid, exch, pos, area, act = _colocation_tensors(co)
    args = (pop, fid, exch, pos, area, act, None, None, key)
    rows = []
    for donate in (False, True):
        fn = get_compiled_replay(pop, fid, exch, pos, area, act, batch_fn,
                                 None, key, train_fn, pcfg, method="mlmule",
                                 eval_every=None, eval_fn=None,
                                 donate=donate)
        try:
            ma = fn.lower(*args).compile().memory_analysis()
            alias = int(ma.alias_size_in_bytes)
            peak = (int(ma.argument_size_in_bytes)
                    + int(ma.output_size_in_bytes)
                    + int(ma.temp_size_in_bytes) - alias)
        except Exception:                      # backend without the analysis
            alias, peak = -1, -1
        tag = "donated" if donate else "plain"
        rows.append((f"engine.memory.{tag}.T{steps}", peak, "bytes peak"))
        rows.append((f"engine.memory.{tag}.alias", alias, "bytes aliased"))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    return rows


def run_churn_bench(steps: int = 500, n_mules: int = 20, reps: int = 5,
                    out_path: str = _DEFAULT_CHURN_OUT):
    """Masked vs dense replay on the same schedule shape.

    The activity mask is *data*, not a static: a churned run must reuse the
    dense run's compiled program (zero retraces) and cost essentially the
    same wall clock — the mask only adds elementwise selects to a scan
    dominated by training math. Asserts the warm-run overhead stays <= 10%
    (median of ``reps``) and records it in ``BENCH_churn.json``.
    """
    from repro.mobility import markov_churn_mask

    pop, co, batch_fn, train_fn, pcfg = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)
    co_churn = dict(co)
    co_churn["active"] = markov_churn_mask(11, steps, n_mules,
                                           p_leave=0.05, p_join=0.15)
    active_frac = float(co_churn["active"].mean())

    jit_cache_clear()
    _block(run_population(pop, co, batch_fn, train_fn, pcfg, key)[0])
    before = jit_cache_stats()["traces"]
    _block(run_population(pop, co_churn, batch_fn, train_fn, pcfg, key)[0])
    retraces = jit_cache_stats()["traces"] - before
    assert retraces == 0, "churned same-shape run retraced the dense program"

    def timed(schedule):
        t0 = time.perf_counter()
        _block(run_population(pop, schedule, batch_fn, train_fn, pcfg,
                              key)[0])
        return time.perf_counter() - t0

    dense_s = [timed(co) for _ in range(reps)]
    churn_s = [timed(co_churn) for _ in range(reps)]
    dense_med = sorted(dense_s)[reps // 2]
    churn_med = sorted(churn_s)[reps // 2]
    overhead = churn_med / dense_med - 1.0
    assert overhead <= 0.10, \
        f"masked scan overhead {overhead:.1%} exceeds the 10% budget"

    rows = [
        (f"churn.dense_warm.T{steps}", dense_med, "s (median)"),
        (f"churn.masked_warm.T{steps}", churn_med, "s (median)"),
        (f"churn.overhead.T{steps}", overhead * 100.0, "% (masked/dense-1)"),
        ("churn.retraces_masked_call", retraces, "count"),
        ("churn.active_frac", active_frac, "mean mask"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")

    payload = {
        "bench": "engine_micro.run_churn_bench",
        "config": {"steps": steps, "n_mules": n_mules, "reps": reps,
                   "method": "mlmule", "backend": jax.default_backend()},
        "dense_warm_s": round(dense_med, 4),
        "masked_warm_s": round(churn_med, 4),
        "overhead_pct": round(overhead * 100.0, 2),
        "retraces_masked_call": int(retraces),
        "active_frac": round(active_frac, 4),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


def _respawn_with_devices(n_devices: int, out_path: str,
                          flag: str = "--distributed",
                          out_flag: str = "--out-distributed") -> None:
    """Re-exec a device-hungry bench in a child with N forced host devices."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}"
                        ).strip()
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep +
                         env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    env["_REPRO_DIST_BENCH_CHILD"] = "1"   # forbid a second respawn
    subprocess.run([sys.executable, "-m", "benchmarks.engine_micro",
                    flag, out_flag, out_path],
                   env=env, cwd=root, check=True)


def run_encounter_bench(n_mules: int = 8192, reps: int = 5,
                        n_devices: int = 8, ring_mules: int = 8192,
                        ring_steps: int = 9, ring_areas: int = 8,
                        out_path: str = _DEFAULT_ENC_OUT):
    """Peer-encounter mix: tiled kernel vs the retired dense path, plus the
    locality-aware ring (pruned AND unpruned) vs a single-host warm gossip
    replay at the same M=8192.

    The dense path builds the full [M, M] encounter matrix, normalizes it,
    and runs one ``masked_group_mean`` matmul *per model leaf* — O(M^2)
    reads per leaf on top of the O(M^2 * D) MACs. The fused op
    (``repro.kernels.encounter_mix``) flattens the model pytree once and
    computes distance test + row-normalized mix tile by tile, so the
    [M, M] matrix and the per-leaf passes never exist. Asserts the fused
    warm step beats the dense warm step and records both in
    ``BENCH_encounter.json``.

    The ring rows replay one gossip workload three ways: single-host, the
    bucket-sharded pruned ring (``DistributedConfig.ring_prune=True``, the
    engine default), and the same ring with pruning off (every hop streams
    every block — the pre-locality behaviour). Mules carry ``ring_areas``
    balanced random areas and are ordered by ``bucket_mule_order`` before
    sharding, so the area-bitmask predicate can prove remote hops empty;
    the recorded telemetry (hops executed/pruned per exchange step, payload
    bytes per exchange, bucket-locality fraction) makes a future regression
    diagnosable. ``ring_vs_host`` — the pruned ring's speedup — is gated by
    ``bench_gate`` alongside the tiled-kernel headline; both ring variants
    must agree bitwise (asserted here, and pinned with scenario coverage in
    ``tests/test_ring_exchange.py``). Needs ``n_devices``; without them the
    bench re-execs itself like ``run_distributed_bench``.
    """
    import dataclasses

    import numpy as np
    from repro.baselines.gossip import (area_bit_collision_rate,
                                        encounter_matrix,
                                        flatten_population, ring_hop_mask,
                                        unflatten_population)
    from repro.core.aggregation import masked_group_mean
    from repro.core.distributed import (DistributedConfig,
                                        bucket_locality_fraction,
                                        bucket_mule_order,
                                        reorder_colocation,
                                        reorder_mule_state,
                                        to_distributed_state)
    from repro.kernels.encounter_mix import encounter_mix

    out_path = os.path.abspath(out_path)
    if jax.device_count() < n_devices:
        if os.environ.get("_REPRO_DIST_BENCH_CHILD"):
            raise RuntimeError(
                f"need >= {n_devices} devices but forcing host devices "
                f"yielded {jax.device_count()} on backend "
                f"{jax.default_backend()!r}")
        _respawn_with_devices(n_devices, out_path, flag="--encounter",
                              out_flag="--out-encounter")
        with open(out_path) as f:
            payload = json.load(f)
        return [(k, v, "from respawned child") for k, v in payload.items()
                if isinstance(v, (int, float))]

    # -- tiled kernel vs dense [M, M] + per-leaf group mean ------------------
    # the paper's mobile regime at ROADMAP scale: a large population of
    # tiny on-device models (M >> D), a pytree of many small leaves —
    # exactly where the retired path pays one [M, M] normalization read
    # per leaf and the [M, M] matrix itself dominates the traffic
    m = n_mules
    leaf_shapes = ([(8,)] * 4 + [(16,)] * 4 + [(4, 4)] * 4
                   + [(6, 16)] * 2 + [(16, 4)] * 2)      # 16 leaves, D=480
    models = {f"l{i}": jax.random.normal(jax.random.PRNGKey(i), (m,) + s)
              for i, s in enumerate(leaf_shapes)}
    d_total = sum(int(np.prod(l.shape[1:]))
                  for l in jax.tree.leaves(models))
    ks = jax.random.split(jax.random.PRNGKey(99), 3)
    pos = jax.random.uniform(ks[0], (m, 2))
    area = jax.random.randint(ks[1], (m,), 0, 2)
    active = jax.random.uniform(ks[2], (m,)) < 0.9
    radius = 0.1

    @jax.jit
    def dense_mix(models, pos, area, active):
        enc = encounter_matrix(pos, area, radius, active).astype(jnp.float32)
        return masked_group_mean(models, enc)

    @jax.jit
    def fused_mix(models, pos, area, active):
        flat, spec = flatten_population(models)
        mixed, mass = encounter_mix(pos, area, active, flat, radius=radius,
                                    backend="pallas", block_m=512)
        return unflatten_population(mixed, spec), mass

    def timed(fn):
        _block(fn(models, pos, area, active)[0])       # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            _block(fn(models, pos, area, active)[0])
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[reps // 2]

    dense_s = timed(dense_mix)
    fused_s = timed(fused_mix)
    assert fused_s < dense_s, \
        f"tiled encounter_mix ({fused_s:.3f}s) lost to the dense path " \
        f"({dense_s:.3f}s)"

    # -- locality-aware ring vs single-host warm gossip replay ---------------
    # same population scale as the kernel half: the regime the ROADMAP item
    # names, where a ring hop moves a [M/n, D] block and locality decides
    # whether it moves at all. Balanced random areas, bucket-ordered before
    # sharding, so each shard is (nearly) one spatial bucket.
    mesh = jax.make_mesh((1, n_devices), ("pod", "data"))
    rm, rt = ring_mules, ring_steps
    rd = 8                                            # per-mule model dim
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    r_area = np.asarray(jax.random.permutation(
        ks[0], np.arange(rm) % ring_areas)).astype(np.int32)
    co_ring = {
        "fixed_id": np.full((rt, rm), -1, np.int32),  # peer exchange only
        "exchange": np.zeros((rt, rm), bool),
        "pos": np.asarray(jax.random.uniform(ks[1], (rt, rm, 2)),
                          np.float32),
        "area": r_area,
    }
    X = jax.random.normal(jax.random.PRNGKey(50), (rm, 12, rd))
    Y = jax.random.normal(jax.random.PRNGKey(60), (rm, 12))

    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(key, t):
        idx = jax.random.randint(key, (rm, 4), 0, X.shape[1])
        return {"fixed": None,
                "mule": (jnp.take_along_axis(X, idx[:, :, None], 1),
                         jnp.take_along_axis(Y, idx, 1))}

    pcfg = PopulationConfig(mode="mobile", n_fixed=8, n_mules=rm)
    pop = init_population(jax.random.PRNGKey(1),
                          lambda k: {"w": jax.random.normal(k, (rd,))}, pcfg)

    # bucket sharding: order mules by area at colocation build time (state
    # rows follow their columns); migrate_mules is the mid-run re-bucketing
    # primitive this bench doesn't need (areas are static here)
    order = bucket_mule_order(r_area)
    co_ring = reorder_colocation(co_ring, order)
    pop = reorder_mule_state(pop, order)
    key = jax.random.PRNGKey(7)

    def warm(fn):
        out = fn()[0]
        _block(out)
        t0 = time.perf_counter()
        _block(fn()[0])
        return time.perf_counter() - t0, out

    host_s, host_out = warm(lambda: run_population(
        pop, co_ring, batch_fn, train_fn, pcfg, key, method="gossip"))
    dcfg = DistributedConfig(pop=pcfg)                  # ring_prune=True
    dstate = to_distributed_state(pop, dcfg)
    ring_s, ring_out = warm(lambda: run_population_distributed(
        dstate, co_ring, batch_fn, train_fn, dcfg, mesh, key,
        method="gossip"))
    dcfg_u = dataclasses.replace(dcfg, ring_prune=False)
    unpruned_s, unpruned_out = warm(lambda: run_population_distributed(
        to_distributed_state(pop, dcfg_u), co_ring, batch_fn, train_fn,
        dcfg_u, mesh, key, method="gossip"))
    for a, b in zip(jax.tree.leaves(ring_out["mule_models"]),
                    jax.tree.leaves(unpruned_out["mule_models"])):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "pruned and unpruned rings disagree"
    del host_out

    # -- ring telemetry (the host-side mirror of the in-ring predicate) ------
    n_shards = int(mesh.shape["data"])
    m_loc = rm // n_shards
    need = np.asarray(ring_hop_mask(co_ring["area"], None, n_shards))
    hops_executed = int(need.sum())
    hops_pruned = n_shards - hops_executed
    # per executed remote hop every shard sends its (pos f32[2] + area i32 +
    # active bool + flat f32[D]) block; the predicate itself costs one
    # [n, 32] f32 psum per exchange step
    payload_bytes = (n_shards * max(hops_executed - 1, 0)
                     * m_loc * (8 + 4 + 1 + 4 * rd)
                     + n_shards * n_shards * 32 * 4)
    locality = bucket_locality_fraction(co_ring["area"], n_shards)
    # effective predicate width this run resolves to (ring_bits=0 -> auto)
    ring_bits = 64 if int(co_ring["area"].max()) >= 32 else 32
    collision = area_bit_collision_rate(co_ring["area"], n_bits=ring_bits)

    rows = [
        (f"encounter.dense_warm.M{m}", dense_s, "s (median)"),
        (f"encounter.tiled_warm.M{m}", fused_s, "s (median)"),
        (f"encounter.speedup.M{m}", dense_s / fused_s, "x (dense/tiled)"),
        (f"encounter.host_gossip_warm.M{rm}.T{rt}", host_s, "s total"),
        (f"encounter.ring_gossip_warm.M{rm}.T{rt}", ring_s,
         "s total (pruned)"),
        (f"encounter.ring_unpruned_warm.M{rm}.T{rt}", unpruned_s,
         "s total"),
        (f"encounter.ring_vs_host.M{rm}.T{rt}", host_s / ring_s,
         "x (host/pruned ring, gated)"),
        (f"encounter.ring_vs_host_unpruned.M{rm}.T{rt}",
         host_s / unpruned_s, "x (host/unpruned ring)"),
        (f"encounter.hops.n{n_shards}", hops_executed,
         f"executed per exchange step ({hops_pruned} pruned)"),
        (f"encounter.payload_bytes", payload_bytes, "B per exchange step"),
        (f"encounter.bucket_locality", locality,
         "fraction of same-area pairs shard-local"),
        (f"encounter.area_bits_collision.b{ring_bits}", collision,
         "fraction of areas sharing a summary bit"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")

    payload = {
        "bench": "engine_micro.run_encounter_bench",
        "config": {"n_mules": m, "d_total": int(d_total),
                   "n_leaves": len(jax.tree.leaves(models)),
                   "radius": radius, "reps": reps,
                   "ring_mules": rm, "ring_steps": rt,
                   "ring_areas": ring_areas, "ring_model_d": rd,
                   "mesh": dict(mesh.shape),
                   "backend": jax.default_backend()},
        "dense_warm_s": round(dense_s, 4),
        "tiled_warm_s": round(fused_s, 4),
        "speedup_tiled_vs_dense": round(dense_s / fused_s, 2),
        "host_gossip_warm_s": round(host_s, 4),
        "ring_gossip_warm_s": round(ring_s, 4),
        "ring_vs_host": round(host_s / ring_s, 2),
        "ring_unpruned_warm_s": round(unpruned_s, 4),
        "ring_vs_host_unpruned": round(host_s / unpruned_s, 2),
        "hops_executed": hops_executed,
        "hops_pruned": hops_pruned,
        "payload_bytes_per_exchange": float(payload_bytes),
        "bucket_locality_fraction": round(locality, 4),
        "area_bits_collision_rate": round(collision, 4),
    }
    # the long-trace migration bench merges its re-bucketing telemetry into
    # this same artifact; keep those keys when re-running only this half
    try:
        with open(out_path) as f:
            prior = json.load(f)
        payload.update({k: prior[k] for k in _MIGRATION_KEYS if k in prior})
    except (OSError, ValueError):
        pass
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


_MIGRATION_KEYS = (
    "rebucket_every", "rebucket_threshold", "rebucket_checks",
    "rebucket_swaps", "prune_rate_q1_on", "prune_rate_q4_on",
    "prune_rate_q1_off", "prune_rate_q4_off", "rebucket_prune_retention",
)


def run_migration_bench(n_mules: int = 512, n_steps: int = 4096,
                        n_shards: int = 16, rebucket_every: int = 64,
                        threshold: float = 0.001,
                        out_path: str = _DEFAULT_ENC_OUT):
    """Long-trace migration: hop-prune rate over time, re-bucketing on/off.

    Replays a persistent-relocation area trace (``2 * n_shards`` cities so
    bucketing has pruning headroom; 1-in-8 mules permanently moves to a
    random other city at a random step — the paper's rare inter-area
    traveler made permanent, the regime where build-time bucketing decays
    but re-bucketing recovers; round-trip travel visits never prune at any
    cadence because ~a quarter of the population is instantaneously away
    from its bucket) through the *exact* host-side mirror of the ring's
    pruning predicate (``ring_hop_mask`` semantics, vectorized over steps,
    64-bit masks since 32 areas overflow 32 bits) under two shard layouts:

    - **off** — the PR-7 behavior: mules bucket-ordered once at build time;
      as the population migrates the shard/area alignment decays and the
      prune rate drifts toward zero (every hop executed);
    - **on** — the drift-check + argsort swap rule the engine drivers run
      (same cadence, same threshold, same stable re-sort), applied at every
      ``rebucket_every`` boundary.

    Telemetry is deterministic (no timing): per-quartile mean prune rates
    for both layouts, swap/check counts, and the retention ratio
    ``prune_rate_q4_on / prune_rate_q1_on`` — gated by ``bench_gate`` so a
    future change that lets the decay back in fails the lane. Keys merge
    into ``BENCH_encounter.json`` next to the ring-vs-host rows (run this
    after ``--encounter``, which rewrites the file).
    """
    import numpy as np
    from repro.core.distributed import bucket_mule_order

    out_path = os.path.abspath(out_path)
    n_areas = 2 * n_shards
    rng = np.random.default_rng(0)
    home = np.repeat(np.arange(n_areas), n_mules // n_areas).astype(np.int32)
    area_t = np.broadcast_to(home, (n_steps, n_mules)).copy()   # [T, M]
    for m in rng.choice(n_mules, n_mules // 8, replace=False):
        t_move = int(rng.integers(rebucket_every // 2, n_steps))
        area_t[t_move:, m] = (area_t[t_move - 1, m]
                              + int(rng.integers(1, n_areas))) % n_areas
    n_bits = 64 if int(area_t.max()) >= 32 else 32

    def prune_rates(area_rows):
        """[T, M] bucketed area rows -> [T] prune rate, hops_needed math."""
        t_len, m = area_rows.shape
        blocks = area_rows.reshape(t_len, n_shards, m // n_shards)
        hit = blocks[..., None] % n_bits == np.arange(n_bits)
        bits = hit.any(axis=2)                           # [T, S, n_bits]
        need = np.stack([(bits & np.roll(bits, s, axis=1)).any(axis=(1, 2))
                         for s in range(n_shards)], axis=1)
        return (n_shards - need.sum(axis=1)) / (n_shards - 1)

    order0 = bucket_mule_order(area_t)
    off = prune_rates(area_t[:, order0])

    # the driver's rule, replayed on the host: drift check at every
    # rebucket_every boundary, stable re-sort + re-baseline past threshold
    on = np.empty(n_steps)
    order = order0.copy()
    bucket_area = area_t[0][order]
    checks = swaps = 0
    for t0 in range(0, n_steps, rebucket_every):
        w = slice(t0, min(t0 + rebucket_every, n_steps))
        on[w] = prune_rates(area_t[w][:, order])
        t_end = w.stop
        if t_end < n_steps:
            checks += 1
            area_now = area_t[t_end - 1][order]
            if (area_now != bucket_area).mean() > threshold:
                step = np.argsort(area_now, kind="stable")
                if not np.array_equal(step, np.arange(n_mules)):
                    order = order[step]
                    swaps += 1
                bucket_area = area_now[step]

    def quartiles(x):
        return [float(q.mean()) for q in np.array_split(x, 4)]

    q_on, q_off = quartiles(on), quartiles(off)
    retention = q_on[3] / q_on[0] if q_on[0] else 1.0
    rows = [
        ("migration.prune_rate_q1.on", q_on[0], "first-quartile mean"),
        ("migration.prune_rate_q4.on", q_on[3],
         f"final-quartile mean ({swaps} swaps / {checks} checks)"),
        ("migration.prune_rate_q1.off", q_off[0], "first-quartile mean"),
        ("migration.prune_rate_q4.off", q_off[3],
         "final-quartile mean (build-time bucketing only)"),
        ("migration.retention.on", retention, "q4/q1, gated"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    assert q_on[3] >= 0.9 * q_on[0], \
        f"re-bucketing failed to hold the prune rate: q1={q_on[0]:.3f} " \
        f"q4={q_on[3]:.3f}"

    with open(out_path) as f:
        payload = json.load(f)
    payload["config"]["migration"] = {
        "n_mules": n_mules, "n_steps": n_steps, "n_shards": n_shards,
        "n_areas": n_areas, "n_bits": n_bits,
        "scenario": "persistent-relocation [T, M] area trace"}
    payload.update({
        "rebucket_every": rebucket_every,
        "rebucket_threshold": threshold,
        "rebucket_checks": checks,
        "rebucket_swaps": swaps,
        "prune_rate_q1_on": round(q_on[0], 4),
        "prune_rate_q4_on": round(q_on[3], 4),
        "prune_rate_q1_off": round(q_off[0], 4),
        "prune_rate_q4_off": round(q_off[3], 4),
        "rebucket_prune_retention": round(retention, 4),
    })
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


def run_roofline_bench(n_devices: int = 8, out_path: str = _DEFAULT_ROOF_OUT,
                       reps: int = 3):
    """Roofline autotune sweep -> the ``BENCH_roofline.json`` tuning cache.

    Runs ``repro.launch.autotune.run_roofline``: the compiled engine step
    is decomposed per (method × M) on the single-host engine and per
    method on every candidate (pod, data) mesh shape over the forced
    devices — the rows ``suggest_mesh_shape`` ranks when
    ``run_population_distributed(mesh=None)`` asks for a shape — and every
    feasible ``encounter_mix``/``mule_agg`` block-size candidate is
    measured on the interpret path; the argmin selections land in the
    cache the kernel wrappers read. The headline
    (``tuned_speedup_vs_default``) is gated by ``bench_gate`` like every
    other artifact. Needs ``n_devices`` for the mesh rows; re-execs itself
    with forced host devices otherwise.
    """
    from repro.launch.autotune import run_roofline
    from repro.launch.mesh import make_mule_mesh

    out_path = os.path.abspath(out_path)
    if jax.device_count() < n_devices:
        if os.environ.get("_REPRO_DIST_BENCH_CHILD"):
            raise RuntimeError(
                f"need >= {n_devices} devices but forcing host devices "
                f"yielded {jax.device_count()} on backend "
                f"{jax.default_backend()!r}")
        _respawn_with_devices(n_devices, out_path, flag="--roofline",
                              out_flag="--out-roofline")
        with open(out_path) as f:
            payload = json.load(f)
    else:
        shapes = [(p, n_devices // p) for p in (1, 2, 4)
                  if n_devices % p == 0]
        meshes = [make_mule_mesh(p, d) for p, d in shapes]
        payload = run_roofline(out_path, reps=reps, meshes=meshes)
        print(f"wrote {out_path}")

    rows = []
    for r in payload["roofline"]:
        rows.append((f"roofline.{r['method']}.M{r['n_mules']}"
                     f".mesh{r['mesh']}",
                     r["t_memory_us_per_step"],
                     f"us/step memory term, dominant={r['dominant']}"))
    for e in payload["tuned"]["mule_agg"]:
        rows.append((f"tune.mule_agg.d{e['d']}", e["block_d"],
                     f"block_d ({e['speedup_vs_default']}x vs default)"))
    for e in payload["tuned"]["encounter_mix"]:
        rows.append((f"tune.encounter.m{e['m']}.d{e['d']}",
                     e["block_m"] * 10000 + e["block_d"],
                     f"block_m={e['block_m']} block_d={e['block_d']} "
                     f"({e['speedup_vs_default']}x vs default)"))
    rows.append(("tune.speedup_vs_default",
                 payload["tuned_speedup_vs_default"], "x (geomean, gated)"))
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
    return rows


def run_distributed_bench(n_devices: int = 8, n_mules: int = 64,
                          steps: int = 400, n_seeds: int = 4,
                          out_path: str = _DEFAULT_DIST_OUT):
    """Mule-sharded replay: per-step shard_map dispatch loop vs one scan."""
    import numpy as np
    from repro.core.distributed import DistributedConfig, to_distributed_state
    from repro.scenarios import run_population_distributed_loop

    out_path = os.path.abspath(out_path)    # the child runs with cwd=root
    if jax.device_count() < n_devices:
        # the force-host-devices flag only raises the CPU platform's count;
        # if the child still lands here (e.g. a GPU backend), bail instead
        # of respawning forever
        if os.environ.get("_REPRO_DIST_BENCH_CHILD"):
            raise RuntimeError(
                f"need >= {n_devices} devices but forcing host devices "
                f"yielded {jax.device_count()} on backend "
                f"{jax.default_backend()!r}; run on a CPU host or a "
                f"machine with enough accelerators")
        _respawn_with_devices(n_devices, out_path)
        with open(out_path) as f:            # the child's recorded numbers
            payload = json.load(f)
        return [(k, v, "from respawned child") for k, v in payload.items()
                if isinstance(v, (int, float))]

    mesh = jax.make_mesh((2, n_devices // 2), ("pod", "data"))
    pop, co, batch_fn, train_fn, pcfg = _setup(n_mules=n_mules, steps=steps)
    key = jax.random.PRNGKey(7)

    # -- per-step path: one jitted shard_map dispatch per step ---------------
    # (run_population_distributed_loop — same method step as the scan, so
    # the measured gap is purely the dispatch tax)
    dcfg_ms = DistributedConfig(pop=PopulationConfig(
        mode=pcfg.mode, n_fixed=pcfg.n_fixed, n_mules=pcfg.n_mules,
        freshness=FreshnessConfig(stat="meanstd")))

    def loop(n):
        st = to_distributed_state(pop, dcfg_ms)
        co_n = {k: np.asarray(v)[:n] if np.asarray(v).ndim == 2 else v
                for k, v in co.items()}
        final, _ = run_population_distributed_loop(st, co_n, batch_fn,
                                                   train_fn, dcfg_ms, mesh,
                                                   key)
        jax.block_until_ready(jax.tree.leaves(final["mule_models"])[0])

    loop(3)                                     # compile
    t0 = time.perf_counter()
    loop(steps)
    loop_s = time.perf_counter() - t0

    # -- scan path: the whole replay is one program --------------------------
    dstate = to_distributed_state(pop, dcfg_ms)
    jit_cache_clear()
    t0 = time.perf_counter()
    _block(run_population_distributed(dstate, co, batch_fn, train_fn,
                                      dcfg_ms, mesh, key)[0])
    scan_cold_s = time.perf_counter() - t0
    before = jit_cache_stats()["traces"]
    t0 = time.perf_counter()
    _block(run_population_distributed(dstate, co, batch_fn, train_fn,
                                      dcfg_ms, mesh, key)[0])
    scan_warm_s = time.perf_counter() - t0
    retraces = jit_cache_stats()["traces"] - before
    assert retraces == 0, "warm distributed replay retraced"

    # paper-semantics filter (median/MAD sketch) on the same workload
    dcfg_med = DistributedConfig(pop=pcfg)      # stat="median" default
    dstate_med = to_distributed_state(pop, dcfg_med)
    _block(run_population_distributed(dstate_med, co, batch_fn, train_fn,
                                      dcfg_med, mesh, key)[0])
    t0 = time.perf_counter()
    _block(run_population_distributed(dstate_med, co, batch_fn, train_fn,
                                      dcfg_med, mesh, key)[0])
    scan_med_s = time.perf_counter() - t0

    # -- distributed sweep: vmapped seeds must equal sequential runs ---------
    seeds = list(range(n_seeds))
    setups = [_setup(n_mules=n_mules, steps=steps // 4, seed=s)
              for s in seeds]
    keys = [jax.random.PRNGKey(1000 + s) for s in seeds]
    finals = [run_population_distributed(
        to_distributed_state(st, dcfg_med), sco, batch_fn, train_fn,
        dcfg_med, mesh, k)[0] for (st, sco, _, _, _), k in zip(setups, keys)]
    states = stack_trees([to_distributed_state(s[0], dcfg_med)
                          for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    vf, _ = run_sweep_distributed(states, cos, batch_fn, train_fn, dcfg_med,
                                  mesh, stack_trees(keys))
    bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for i in range(n_seeds)
        for a, b in zip(jax.tree.leaves(jax.tree.map(lambda l: l[i], vf)),
                        jax.tree.leaves(finals[i])))
    assert bitwise, "distributed sweep diverged from sequential runs"

    speedup = loop_s / scan_warm_s
    rows = [
        (f"dist.per_step_loop.T{steps}", loop_s, "s total"),
        (f"dist.scan_cold.T{steps}", scan_cold_s, "s total"),
        (f"dist.scan_warm.T{steps}", scan_warm_s, "s total"),
        (f"dist.scan_warm_median.T{steps}", scan_med_s,
         "s total (median/MAD sketch)"),
        (f"dist.speedup.T{steps}", speedup, "x (per-step/scan-warm)"),
        ("dist.retraces_second_call", retraces, "count"),
        ("dist.sweep_bitwise_equal", int(bitwise), "bool"),
    ]
    for name, val, derived in rows:
        print(f"{name},{val:.3f},{derived}" if isinstance(val, float)
              else f"{name},{val},{derived}")

    payload = {
        "bench": "engine_micro.run_distributed_bench",
        "config": {"n_devices": n_devices, "mesh": dict(mesh.shape),
                   "n_mules": n_mules, "steps": steps, "n_seeds": n_seeds,
                   "method": "mlmule", "backend": jax.default_backend()},
        "per_step_loop_s": round(loop_s, 4),
        "scan_cold_s": round(scan_cold_s, 4),
        "scan_warm_s": round(scan_warm_s, 4),
        "scan_warm_median_sketch_s": round(scan_med_s, 4),
        "speedup_vs_per_step": round(speedup, 2),
        "retraces_second_call": int(retraces),
        "sweep_bitwise_equal": bool(bitwise),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return rows


_SCALE_MARK = "SCALE_CHILD_RESULT "


def _scale_workload(n_mules: int):
    """Linear mule-regression workload for the scale sweep: per-step cost
    is dominated by population/exchange machinery, not model FLOPs, so
    steps/sec tracks the engine, and batches are sampled inside the scan
    (no [M, dataset] tensor competing with the schedule for RSS)."""
    d = 8

    def train_fn(params, b, k):
        xb, yb = b
        g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(k, t):
        kx, ky = jax.random.split(k)
        return {"fixed": None,
                "mule": (jax.random.normal(kx, (n_mules, 2, d)),
                         jax.random.normal(ky, (n_mules, 2)))}

    pcfg = PopulationConfig(mode="mobile", n_fixed=8, n_mules=n_mules)

    def init_pop():
        return init_population(
            jax.random.PRNGKey(1),
            lambda k: {"w": jax.random.normal(k, (d,))}, pcfg)

    return init_pop, batch_fn, train_fn, pcfg


def _scale_child(cfg_json: str) -> None:
    """One (M, engine-mode) measurement, isolated in its own process so
    ``ru_maxrss`` is that engine's peak alone and the two modes can't share
    XLA allocations. Prints one marked JSON line the parent parses.

    Mode ``stream_mp`` is one *rank* of a ``jax.distributed`` cluster
    spawned by ``_spawn_scale_child_cluster``: the coordinator triple
    arrives on the ``REPRO_MP_*`` env vars, so ``initialize_from_env``
    must run before the first jax computation. Every rank prints its own
    result line (digest of the process-allgathered final weights, its own
    peak RSS) and the parent cross-checks the digests."""
    import hashlib
    import resource

    import numpy as np

    from repro.launch.multiprocess import initialize_from_env
    initialize_from_env()

    from repro.mobility import commuter_stream, materialize_generator
    from repro.scenarios import run_population_streamed

    cfg = json.loads(cfg_json)
    m, steps = int(cfg["m"]), int(cfg["steps"])
    chunk_len, mode = int(cfg["chunk_len"]), cfg["mode"]
    init_pop, batch_fn, train_fn, pcfg = _scale_workload(m)
    key = jax.random.PRNGKey(42)
    gen = commuter_stream(0, m, steps)

    retraces = None
    w_host = None
    if mode == "stream_mp":
        # one rank of the multi-process mesh: same streamed engine, same
        # generator, but the chunk replay runs under shard_map over a
        # (1, global-device-count) mule mesh spanning every process
        from jax.experimental import multihost_utils

        from repro.core.distributed import (DistributedConfig,
                                            to_distributed_state)
        from repro.launch.mesh import make_mule_mesh

        mesh = make_mule_mesh(1, jax.device_count())
        dcfg = DistributedConfig(pop=pcfg)
        sched_bytes = gen.schedule_bytes() + chunk_len * m * 14

        def run(g):
            return run_population_streamed(
                to_distributed_state(init_pop(), dcfg), g, batch_fn,
                train_fn, pcfg, key, chunk_len=chunk_len,
                mesh=mesh, dcfg=dcfg)

        _block(run(gen)[0])
        t0 = time.perf_counter()
        final, _ = run(gen)
        _block(final)
        dt = time.perf_counter() - t0
        # horizon-free check, attributable per rank: each process has its
        # own jit cache, so the prefixed counters pin each rank to zero
        pid = jax.process_index()
        before = jit_cache_stats(per_process=True)[f"p{pid}/traces"]
        gen2 = commuter_stream(0, m, (steps // 2) // chunk_len * chunk_len)
        _block(run(gen2)[0])
        retraces = (jit_cache_stats(per_process=True)[f"p{pid}/traces"]
                    - before)
        # every rank hashes the SAME global weights: allgather across the
        # cluster, so digest equality across ranks is bitwise cross-process
        # parity of the final models
        w_host = multihost_utils.process_allgather(
            final["mule_models"]["w"], tiled=True)
    elif mode == "stream":
        # schedule memory: the generator's O(M) params + the [chunk, M]
        # slices live inside one compiled dispatch (fid 4B + exch 1B +
        # pos 8B + active 1B per cell)
        sched_bytes = gen.schedule_bytes() + chunk_len * m * 14
        _block(run_population_streamed(init_pop(), gen, batch_fn, train_fn,
                                       pcfg, key, chunk_len=chunk_len)[0])
        t0 = time.perf_counter()
        final, _ = run_population_streamed(init_pop(), gen, batch_fn,
                                           train_fn, pcfg, key,
                                           chunk_len=chunk_len)
        _block(final)
        dt = time.perf_counter() - t0
        # the compiled chunk program must be horizon-free: a half-length
        # generator replays through the same cache entry, zero new traces
        before = jit_cache_stats()["traces"]
        gen2 = commuter_stream(0, m, (steps // 2) // chunk_len * chunk_len)
        _block(run_population_streamed(init_pop(), gen2, batch_fn, train_fn,
                                       pcfg, key, chunk_len=chunk_len)[0])
        retraces = jit_cache_stats()["traces"] - before
    else:
        co = materialize_generator(gen, chunk_len=max(chunk_len, 64))
        sched_bytes = sum(
            np.asarray(co[k]).nbytes
            for k in ("fixed_id", "exchange", "pos", "active", "area"))
        _block(run_population(init_pop(), co, batch_fn, train_fn, pcfg, key,
                              donate=True)[0])
        t0 = time.perf_counter()
        final, _ = run_population(init_pop(), co, batch_fn, train_fn, pcfg,
                                  key, donate=True)
        _block(final)
        dt = time.perf_counter() - t0

    if w_host is None:
        w_host = np.asarray(final["mule_models"]["w"])
    w = np.ascontiguousarray(np.asarray(w_host, np.float32))
    out = {
        "m": m, "mode": mode,
        "steps_per_sec": steps / dt, "wall_s": dt,
        "schedule_bytes": int(sched_bytes),
        "peak_rss_mb": resource.getrusage(
            resource.RUSAGE_SELF).ru_maxrss / 1024.0,   # linux: KB units
        "digest": hashlib.sha256(w.tobytes()).hexdigest(),
    }
    if mode == "stream_mp":
        out["process_id"] = int(jax.process_index())
        out["n_processes"] = int(jax.process_count())
    if retraces is not None:
        out["retraces_new_t"] = int(retraces)
    print(_SCALE_MARK + json.dumps(out))


def _child_env() -> dict:
    """Env for scale children: repo root + src on PYTHONPATH so
    ``-m benchmarks.engine_micro`` resolves regardless of cwd."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep + root +
                         os.pathsep +
                         env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    return env


def _spawn_scale_child(cfg: dict) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run([sys.executable, "-m", "benchmarks.engine_micro",
                          "--scale-child", json.dumps(cfg)],
                         env=_child_env(), cwd=root, check=True,
                         capture_output=True, text=True)
    for line in res.stdout.splitlines():
        if line.startswith(_SCALE_MARK):
            return json.loads(line[len(_SCALE_MARK):])
    raise RuntimeError(f"scale child produced no result:\n"
                       f"{res.stdout}\n{res.stderr}")


def _spawn_scale_child_cluster(cfg: dict, n_processes: int,
                               devices_per_process: int = 1) -> list:
    """Run one ``stream_mp`` measurement as an N-process local cluster.

    ``spawn_local_cluster`` launches every rank concurrently (the
    coordinator blocks until the whole cluster dials in); each rank
    prints its own marked result line and this returns them sorted by
    rank. Any rank failing (non-zero exit or no result line) raises with
    that rank's merged stdout/stderr."""
    from repro.launch.multiprocess import spawn_local_cluster

    results = spawn_local_cluster(
        [sys.executable, "-m", "benchmarks.engine_micro",
         "--scale-child", json.dumps(cfg)],
        n_processes, devices_per_process,
        base_env=_child_env(), timeout=3600)
    ranks = []
    for pid, res in enumerate(results):
        if res.returncode != 0:
            raise RuntimeError(f"scale cluster rank {pid} exited "
                               f"{res.returncode}:\n{res.stdout}")
        for line in res.stdout.splitlines():
            if line.startswith(_SCALE_MARK):
                ranks.append(json.loads(line[len(_SCALE_MARK):]))
                break
        else:
            raise RuntimeError(f"scale cluster rank {pid} produced no "
                               f"result:\n{res.stdout}")
    return sorted(ranks, key=lambda r: r["process_id"])


def run_scale_bench(ms=(10_000, 32_000, 100_000), steps: int = 96,
                    chunk_len: int = 8, out_path: str = _DEFAULT_SCALE_OUT,
                    mp_m: int = 1_000_000, mp_processes: int = 2,
                    mp_devices_per_process: int = 1, mp_steps: int = 32):
    """Population-scale curve: streamed vs materialized engine over M.

    Per M (each mode in its own subprocess for honest peak-RSS):

    - **stream** — ``run_population_streamed`` over the procedural
      ``commuter_stream`` generator; schedule memory is the generator's
      O(M) params plus one [chunk, M] slice. The child also proves the
      chunk program is horizon-free (a half-length replay adds zero
      traces, reported as ``retraces_new_t``).
    - **materialized** — ``run_population`` over
      ``materialize_generator(...)``'s full ``[T, M]`` tensors, the
      classic engine and the parity reference.

    Parity is cross-process: both children hash their final mule models
    (XLA CPU is deterministic) and the digests must match at EVERY M —
    streaming changes memory, never results. The bench asserts schedule
    bytes stay T-free on the stream side (O(chunk·M) vs the materialized
    O(T·M)) and records both RSS peaks; the gated headline is streamed
    steps/sec at the largest M (``BENCH_scale.json``).

    The curve then extends past single-process: ``mp_processes`` ranks
    are spawned as a local ``jax.distributed`` cluster
    (``_spawn_scale_child_cluster``) running the streamed engine over a
    multi-host mule mesh at ``mp_m`` mules (``mp_steps`` steps — the
    point is scale, not horizon). Every rank hashes the process-
    allgathered final weights; the digests must agree bitwise across
    ranks (``parity_sha_ok``) and each rank's half-horizon replay must
    add zero traces. The multi-process row becomes ``max_m`` and the
    gated ``steps_per_sec_at_max_m`` headline; the ``*_at_max_m``
    memory/schedule keys keep reporting the largest row that has BOTH
    engine modes (the stream-vs-materialized comparison only exists
    single-process — materializing a [T, 10^6] schedule is the thing
    this engine exists to avoid).
    """
    out_path = os.path.abspath(out_path)
    ms = sorted(int(m) for m in ms)
    curve = []
    for m in ms:
        base = {"m": m, "steps": steps, "chunk_len": chunk_len}
        s = _spawn_scale_child({**base, "mode": "stream"})
        r = _spawn_scale_child({**base, "mode": "materialized"})
        assert s["digest"] == r["digest"], \
            f"M={m}: streamed models != materialized models (parity broken)"
        assert s["retraces_new_t"] == 0, \
            f"M={m}: chunk program retraced on a new horizon"
        assert s["schedule_bytes"] < r["schedule_bytes"], \
            f"M={m}: streaming failed to shrink the schedule"
        row = {
            "m": m,
            "stream_steps_per_sec": round(s["steps_per_sec"], 2),
            "materialized_steps_per_sec": round(r["steps_per_sec"], 2),
            "stream_schedule_bytes": s["schedule_bytes"],
            "materialized_schedule_bytes": r["schedule_bytes"],
            "peak_rss_stream_mb": round(s["peak_rss_mb"], 1),
            "peak_rss_materialized_mb": round(r["peak_rss_mb"], 1),
            "parity_bitwise": True,
            "retraces_new_t": s["retraces_new_t"],
        }
        curve.append(row)
        print(f"scale.M{m}: stream {row['stream_steps_per_sec']:.1f} "
              f"steps/s ({row['stream_schedule_bytes'] / 1e6:.1f} MB sched, "
              f"rss {row['peak_rss_stream_mb']:.0f} MB) | materialized "
              f"{row['materialized_steps_per_sec']:.1f} steps/s "
              f"({row['materialized_schedule_bytes'] / 1e6:.1f} MB sched, "
              f"rss {row['peak_rss_materialized_mb']:.0f} MB) | parity OK")

    sp_top = curve[-1]
    mp_row = None
    if mp_processes and mp_processes > 1:
        ranks = _spawn_scale_child_cluster(
            {"m": int(mp_m), "steps": int(mp_steps),
             "chunk_len": chunk_len, "mode": "stream_mp"},
            mp_processes, mp_devices_per_process)
        parity_sha_ok = len({r["digest"] for r in ranks}) == 1
        assert parity_sha_ok, \
            (f"M={mp_m}: final-weight digests diverged across ranks: "
             f"{[r['digest'][:12] for r in ranks]}")
        assert all(r["retraces_new_t"] == 0 for r in ranks), \
            f"M={mp_m}: a rank's chunk program retraced on a new horizon"
        r0 = ranks[0]
        mp_row = {
            "m": int(mp_m), "mode": "stream_mp",
            "n_processes": int(mp_processes),
            "stream_steps_per_sec": round(r0["steps_per_sec"], 2),
            "stream_schedule_bytes": r0["schedule_bytes"],
            "rss_per_process_mb": [round(r["peak_rss_mb"], 1)
                                   for r in ranks],
            "parity_sha_ok": parity_sha_ok,
            "retraces_new_t": max(r["retraces_new_t"] for r in ranks),
        }
        curve.append(mp_row)
        print(f"scale.M{int(mp_m)}.x{mp_processes}proc: stream "
              f"{mp_row['stream_steps_per_sec']:.2f} steps/s "
              f"({mp_row['stream_schedule_bytes'] / 1e6:.1f} MB sched, "
              f"rss/proc {mp_row['rss_per_process_mb']} MB) | "
              f"cross-process sha parity OK")

    top = curve[-1]
    payload = {
        "bench": "engine_micro.run_scale_bench",
        "config": {"ms": ms, "steps": steps, "chunk_len": chunk_len,
                   "scenario": "streaming_commuter", "method": "mlmule",
                   "model": "linear_d8", "backend": jax.default_backend(),
                   "mp_m": int(mp_m), "mp_processes": int(mp_processes),
                   "mp_devices_per_process": int(mp_devices_per_process),
                   "mp_steps": int(mp_steps)},
        "curve": curve,
        "max_m": top["m"],
        "steps_per_sec_at_max_m": top["stream_steps_per_sec"],
        "parity_bitwise_all_m": all(r["parity_bitwise"] for r in curve
                                    if "parity_bitwise" in r),
        # memory/schedule comparisons need both engine modes, which only
        # the single-process rows have — these keys stay pinned to the
        # largest such row even when the mp row extends max_m past it
        "stream_schedule_bytes_at_max_m": sp_top["stream_schedule_bytes"],
        "materialized_schedule_bytes_at_max_m":
            sp_top["materialized_schedule_bytes"],
        "schedule_bytes_ratio": round(
            sp_top["materialized_schedule_bytes"]
            / sp_top["stream_schedule_bytes"], 2),
        "peak_rss_stream_mb_at_max_m": sp_top["peak_rss_stream_mb"],
        "peak_rss_materialized_mb_at_max_m":
            sp_top["peak_rss_materialized_mb"],
        "retraces_new_t": max(r["retraces_new_t"] for r in curve),
        "n_processes": int(mp_processes) if mp_row else 1,
        "rss_per_process_mb": (mp_row["rss_per_process_mb"] if mp_row
                               else [sp_top["peak_rss_stream_mb"]]),
        "parity_sha_ok": bool(mp_row["parity_sha_ok"]) if mp_row else True,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return curve


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="run only the sweep benchmark")
    ap.add_argument("--distributed", action="store_true",
                    help="run only the distributed benchmark")
    ap.add_argument("--churn", action="store_true",
                    help="run only the churn-mask overhead benchmark")
    ap.add_argument("--encounter", action="store_true",
                    help="run only the encounter-mix benchmark")
    ap.add_argument("--migration", action="store_true",
                    help="run only the long-trace migration benchmark "
                         "(hop-prune rate over time with mid-run "
                         "re-bucketing on vs off; merges telemetry into "
                         "the encounter artifact — run after --encounter)")
    ap.add_argument("--roofline", action="store_true",
                    help="run only the roofline autotune sweep")
    ap.add_argument("--scale", action="store_true",
                    help="run only the population-scale curve (streamed vs "
                         "materialized engine over M, subprocess children "
                         "for peak-RSS isolation)")
    ap.add_argument("--scale-child", metavar="JSON",
                    help="internal: run one (M, mode) scale measurement in "
                         "this process and print its result line (one rank "
                         "of a cluster when spawned with REPRO_MP_* env)")
    ap.add_argument("--scale-processes", type=int, default=2,
                    help="ranks for the multi-process scale row "
                         "(0/1 skips it)")
    ap.add_argument("--scale-mp-m", type=int, default=1_000_000,
                    help="population for the multi-process scale row")
    ap.add_argument("--gate-baseline", metavar="DIR",
                    help="after producing artifacts, regression-gate them "
                         "against the committed copies in DIR "
                         "(benchmarks.bench_gate; exits non-zero on "
                         "regression)")
    ap.add_argument("--out", default=_DEFAULT_OUT)
    ap.add_argument("--out-distributed", default=_DEFAULT_DIST_OUT)
    ap.add_argument("--out-churn", default=_DEFAULT_CHURN_OUT)
    ap.add_argument("--out-encounter", default=_DEFAULT_ENC_OUT)
    ap.add_argument("--out-roofline", default=_DEFAULT_ROOF_OUT)
    ap.add_argument("--out-scale", default=_DEFAULT_SCALE_OUT)
    args = ap.parse_args()
    if args.scale_child:
        _scale_child(args.scale_child)
        raise SystemExit(0)
    produced = []                    # (artifact name, fresh path) per bench
    if args.distributed:
        run_distributed_bench(out_path=args.out_distributed)
        produced.append(("BENCH_distributed.json", args.out_distributed))
    elif args.sweep:
        run_sweep_bench(out_path=args.out)
        produced.append(("BENCH_sweep.json", args.out))
    elif args.churn:
        run_churn_bench(out_path=args.out_churn)
        produced.append(("BENCH_churn.json", args.out_churn))
    elif args.encounter:
        run_encounter_bench(out_path=args.out_encounter)
        produced.append(("BENCH_encounter.json", args.out_encounter))
    elif args.migration:
        run_migration_bench(out_path=args.out_encounter)
        produced.append(("BENCH_encounter.json", args.out_encounter))
    elif args.roofline:
        run_roofline_bench(out_path=args.out_roofline)
        produced.append(("BENCH_roofline.json", args.out_roofline))
    elif args.scale:
        run_scale_bench(out_path=args.out_scale,
                        mp_m=args.scale_mp_m,
                        mp_processes=args.scale_processes)
        produced.append(("BENCH_scale.json", args.out_scale))
    else:
        run()
        run_donation_bench()
        run_sweep_bench(out_path=args.out)
        produced.append(("BENCH_sweep.json", args.out))
        run_churn_bench(out_path=args.out_churn)
        produced.append(("BENCH_churn.json", args.out_churn))
        run_encounter_bench(out_path=args.out_encounter)
        run_migration_bench(out_path=args.out_encounter)
        produced.append(("BENCH_encounter.json", args.out_encounter))
        run_distributed_bench(out_path=args.out_distributed)
        produced.append(("BENCH_distributed.json", args.out_distributed))
        run_roofline_bench(out_path=args.out_roofline)
        produced.append(("BENCH_roofline.json", args.out_roofline))
        run_scale_bench(out_path=args.out_scale)
        produced.append(("BENCH_scale.json", args.out_scale))
    if args.gate_baseline:
        from benchmarks import bench_gate
        results = [bench_gate.gate_artifact(
            name, bench_gate._load(os.path.join(args.gate_baseline, name)),
            bench_gate._load(path)) for name, path in produced]
        for r in results:
            print(r.row())
        if any(not r.ok for r in results):
            raise SystemExit(1)
