"""Fig 6/7 analogue: mobile-device training (Shards CIFAR-like) over time.

Methods: Gossip, OppCL, Local-Only, ML Mule, ML Mule + Gossip, at
P_cross in {0, 0.1, 0.5}. Validated claim: ML Mule converges faster and to
higher accuracy than Gossip/OppCL/Local; Mule+Gossip ~ Mule.
"""
from __future__ import annotations

import json

from benchmarks.common import ExperimentConfig, run_experiment

METHODS = ("mlmule", "gossip", "oppcl", "local", "mlmule+gossip")


def run(full: bool = False, seed: int = 0):
    steps = 900 if full else 240
    p_list = ["0", "0.1", "0.5"] if full else ["0", "0.5"]
    rows = []
    for p in p_list:
        for method in METHODS:
            cfg = ExperimentConfig(task="image", mode="mobile", method=method,
                                   dist="shards", pattern=p, steps=steps,
                                   seed=seed)
            r = run_experiment(cfg)
            rows.append({"p_cross": p, "method": method, "trace": r["trace"],
                         "final_acc": r["pre_local_acc"], "wall_s": r["wall_s"]})
            print(f"fig6,{p},{method},{r['pre_local_acc']:.4f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(full=args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
