"""Fig 6/7 analogue: mobile-device training (Shards CIFAR-like) over time.

Methods: Gossip, OppCL, Local-Only, ML Mule, ML Mule + Gossip, at
P_cross in {0, 0.1, 0.5}. Validated claim: ML Mule converges faster and to
higher accuracy than Gossip/OppCL/Local; Mule+Gossip ~ Mule.

Seed-averaged like the paper's curves: each (P_cross, method) cell replays
every seed in ONE vmapped compiled program (``run_sweep_experiment``), and
all five methods ride the scan engine's jit cache.
"""
from __future__ import annotations

import json

from benchmarks.common import (METHODS_MOBILE, ExperimentConfig,
                               run_sweep_experiment)

METHODS = METHODS_MOBILE


def run(full: bool = False, seeds=(0,)):
    steps = 900 if full else 240
    p_list = ["0", "0.1", "0.5"] if full else ["0", "0.5"]
    rows = []
    for p in p_list:
        cfg = ExperimentConfig(task="image", mode="mobile", dist="shards",
                               pattern=p, steps=steps)
        r = run_sweep_experiment(cfg, seeds, methods=METHODS)
        for method in METHODS:
            d = r["methods"][method]
            rows.append({"p_cross": p, "method": method,
                         "seeds": list(seeds),
                         "trace": list(zip(r["eval_steps"], d["mean_acc"])),
                         "acc_per_seed": d["final_acc"],
                         "final_acc": d["mean_final_acc"],
                         "wall_s": r["wall_s"]})
            print(f"fig6,{p},{method},{d['mean_final_acc']:.4f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..N-1) averaged per cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(full=args.full, seeds=tuple(range(args.seeds)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
