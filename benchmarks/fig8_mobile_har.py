"""Fig 8/9 analogue: mobile-device training on IMU HAR (EgoExo4D-like).

LSTM-CNN over procedural IMU windows whose activity-by-location density
mirrors the paper's Table 2. Validated claim: ML Mule > Gossip/OppCL/Local
(Local cannot extract enough features from its limited slice).
"""
from __future__ import annotations

import json

from benchmarks.common import ExperimentConfig, run_experiment

METHODS = ("mlmule", "gossip", "oppcl", "local", "mlmule+gossip")


def run(full: bool = False, seed: int = 0):
    steps = 700 if full else 200
    p_list = ["0", "0.1", "0.5"] if full else ["0.1"]
    rows = []
    for p in p_list:
        for method in METHODS:
            cfg = ExperimentConfig(task="har", mode="mobile", method=method,
                                   pattern=p, steps=steps, seed=seed,
                                   batch=12, lr=0.03)
            r = run_experiment(cfg)
            rows.append({"p_cross": p, "method": method, "trace": r["trace"],
                         "final_acc": r["pre_local_acc"], "wall_s": r["wall_s"]})
            print(f"fig8,{p},{method},{r['pre_local_acc']:.4f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(full=args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
