"""Fig 8/9 analogue: mobile-device training on IMU HAR (EgoExo4D-like).

LSTM-CNN over procedural IMU windows whose activity-by-location density
mirrors the paper's Table 2. Validated claim: ML Mule > Gossip/OppCL/Local
(Local cannot extract enough features from its limited slice).

Seed-averaged on the batched sweep engine: one vmapped compiled program
per (P_cross, method) cell via ``run_sweep_experiment``.
"""
from __future__ import annotations

import json

from benchmarks.common import (METHODS_MOBILE, ExperimentConfig,
                               run_sweep_experiment)

METHODS = METHODS_MOBILE


def run(full: bool = False, seeds=(0,)):
    steps = 700 if full else 200
    p_list = ["0", "0.1", "0.5"] if full else ["0.1"]
    rows = []
    for p in p_list:
        cfg = ExperimentConfig(task="har", mode="mobile", pattern=p,
                               steps=steps, batch=12, lr=0.03)
        r = run_sweep_experiment(cfg, seeds, methods=METHODS)
        for method in METHODS:
            d = r["methods"][method]
            rows.append({"p_cross": p, "method": method,
                         "seeds": list(seeds),
                         "trace": list(zip(r["eval_steps"], d["mean_acc"])),
                         "acc_per_seed": d["final_acc"],
                         "final_acc": d["mean_final_acc"],
                         "wall_s": r["wall_s"]})
            print(f"fig8,{p},{method},{d['mean_final_acc']:.4f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..N-1) averaged per cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(full=args.full, seeds=tuple(range(args.seeds)))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
