"""Beyond-paper ablation: does the freshness filter (Sec 3.1) matter?

The filter binds when mules disappear for long stretches and return with
stale snapshots — the sparse 4Q (Foursquare-like) trace regime the paper
highlights. We compare ML Mule with the dynamic threshold vs accept-all
under both the dense random walk (filter should be ~neutral) and sparse
traces (filter should help).

  PYTHONPATH=src python -m benchmarks.ablation_freshness
"""
from __future__ import annotations

from benchmarks.common import ExperimentConfig, run_experiment


def run(steps: int = 240, seed: int = 0):
    rows = []
    for pattern in ("0.1", "4q"):
        for off in (False, True):
            cfg = ExperimentConfig(mode="fixed", method="mlmule",
                                   dist="dir0.01", pattern=pattern,
                                   steps=steps, seed=seed, freshness_off=off)
            r = run_experiment(cfg)
            tag = "accept-all" if off else "filtered"
            rows.append({"pattern": pattern, "filter": not off,
                         "pre": r["pre_local_acc"], "post": r["post_local_acc"]})
            print(f"ablation_freshness,{pattern},{tag},"
                  f"{r['pre_local_acc']:.4f},{r['post_local_acc']:.4f}")
    return rows


if __name__ == "__main__":
    run()
