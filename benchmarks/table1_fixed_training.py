"""Table 1 analogue: fixed-device training accuracy across distributions.

Paper: CIFAR-100 20-super-class task, 8 fixed devices, 20 mules; methods
CFL/FedAS/FedAvg/Local vs ML Mule at P_cross in {0, 0.1, 0.5} and 4Q traces.
Here: procedural image dataset at reduced scale (CPU); --full approaches the
paper's sizes. The claim validated is the ORDERING: ML Mule >= federated
baselines >= Local under non-IID, and the P_cross trends.
"""
from __future__ import annotations

import json

from benchmarks.common import ExperimentConfig, run_experiment


def run(full: bool = False, dists=None, seed: int = 0):
    dists = dists or (["dir0.01", "iid"] if not full
                      else ["dir0.001", "dir0.01", "dir0.1", "iid"])
    steps = 900 if full else 240
    rows = []
    for dist in dists:
        for method in ("local", "fedavg", "cfl", "fedas"):
            cfg = ExperimentConfig(mode="fixed", method=method, dist=dist,
                                   steps=steps, seed=seed)
            r = run_experiment(cfg)
            rows.append({"dist": dist, "method": method, "pattern": "-",
                         **{k: r[k] for k in ("pre_local_acc", "post_local_acc",
                                              "wall_s")}})
            print(f"table1,{dist},{method},-,"
                  f"{r['pre_local_acc']:.4f},{r['post_local_acc']:.4f}")
        patterns = ["0", "0.1", "0.5", "4q"] if full else ["0", "0.5", "4q"]
        for pattern in patterns:
            cfg = ExperimentConfig(mode="fixed", method="mlmule", dist=dist,
                                   pattern=pattern, steps=steps, seed=seed)
            r = run_experiment(cfg)
            rows.append({"dist": dist, "method": "mlmule", "pattern": pattern,
                         **{k: r[k] for k in ("pre_local_acc", "post_local_acc",
                                              "wall_s")}})
            print(f"table1,{dist},mlmule,{pattern},"
                  f"{r['pre_local_acc']:.4f},{r['post_local_acc']:.4f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(full=args.full)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
