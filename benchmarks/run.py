"""Benchmark suite entry: one benchmark per paper table/figure.

``python -m benchmarks.run [--full] [--only table1,fig6,...]``
prints ``name,us_per_call(or metric),derived`` CSV lines per benchmark.
The ``sweep`` lane also writes ``benchmarks/BENCH_sweep.json`` (sequential
vs vmapped sweep throughput — the artifact CI uploads).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--only", default=None,
                    help="comma list: kernels,engine,proto,table1,fig6,fig8")
    ap.add_argument("--outdir", default="benchmarks/results")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else [
        "kernels", "engine", "sweep", "proto", "table1", "fig6", "fig8"]
    os.makedirs(args.outdir, exist_ok=True)
    results = {}

    print("name,value,derived")
    if "kernels" in only:
        from benchmarks import kernels_micro
        results["kernels"] = kernels_micro.run()
    if "engine" in only:
        from benchmarks import engine_micro
        results["engine"] = engine_micro.run()
    if "sweep" in only:
        from benchmarks import engine_micro
        results["sweep"] = engine_micro.run_sweep_bench()
    if "proto" in only:
        from benchmarks import prototype_timing
        results["proto"] = prototype_timing.run()
    if "table1" in only:
        from benchmarks import table1_fixed_training
        t0 = time.time()
        results["table1"] = table1_fixed_training.run(full=args.full)
        print(f"table1.wall_s,{time.time()-t0:.1f},")
    if "fig6" in only:
        from benchmarks import fig6_mobile_cifar
        t0 = time.time()
        results["fig6"] = fig6_mobile_cifar.run(full=args.full)
        print(f"fig6.wall_s,{time.time()-t0:.1f},")
    if "fig8" in only:
        from benchmarks import fig8_mobile_har
        t0 = time.time()
        results["fig8"] = fig8_mobile_har.run(full=args.full)
        print(f"fig8.wall_s,{time.time()-t0:.1f},")

    with open(os.path.join(args.outdir, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
