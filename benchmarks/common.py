"""Shared simulation harness for the paper's experiments.

One entry point, ``run_experiment``, reproduces (at configurable scale):
- Table 1  — fixed-device training, CIFAR-like, {IID, Dir(a)} x methods
- Fig 6/7  — mobile-device training, CIFAR-like Shards, vs Gossip/OppCL/Local
- Fig 8/9  — mobile-device training, IMU HAR
under the random-walk mobility model (P_cross) or synthetic 4Q traces.

Reduced sizes by default (CPU container); ``scale='paper'`` approaches the
paper's 20-mule / 8-fixed / 2500-image setup.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import CFLState, cfl_round, fedas_round, fedavg_round
from repro.baselines.cfl import cfl_client_models
from repro.configs.mule_cnn import CNNConfig
from repro.configs.mule_lstm_cnn import LSTMCNNConfig
from repro.core import METHODS_MOBILE, PopulationConfig, init_population
from repro.core.freshness import FreshnessConfig
from repro.data import (dirichlet_partition, iid_partition, make_image_dataset,
                        make_imu_dataset, shards_partition)
from repro.data.partition import train_test_split
from repro.mobility import compact_colocation, synth_foursquare_trace
from repro.models.cnn import (accuracy, cnn_forward, init_cnn, init_lstm_cnn,
                              lstm_cnn_forward, xent_loss)
from repro.scenarios import (get_scenario, run_population,
                             run_population_streamed, run_sweep,
                             scenario_generator, stack_colocations,
                             stack_trees, trace_colocation, walk_colocation)

METHODS_FIXED = ("mlmule", "fedavg", "cfl", "fedas", "local")


@dataclasses.dataclass
class ExperimentConfig:
    task: str = "image"            # image | har
    mode: str = "fixed"            # fixed | mobile
    method: str = "mlmule"
    dist: str = "dir0.01"          # iid | dir<alpha> | shards
    pattern: str = "0.1"           # P_cross value as str, or "4q"
    steps: int = 300
    eval_every: int = 50
    n_mules: int = 12
    n_fixed: int = 8
    batch: int = 16
    lr: float = 0.05
    seed: int = 0
    image_size: int = 16
    n_super: int = 20
    n_sub: int = 5
    n_per_sub: int = 16
    noise: float = 3.0
    train_per_device: int = 32   # local-overfitting regime (paper operating point)
    post_local_epochs: int = 1     # Table 1 "Post-Local" fine-tune
    pretrain_steps: int = 120      # per-device local pretraining to the
                                   # paper's 'accuracy stops improving' point
    freshness_off: bool = False    # ablation: disable the staleness filter
    gamma: float = 0.3
    scenario: str = ""             # registry scenario name; overrides
                                   # mode/dist/task/pattern when set
    distributed: bool = False      # replay on the mule-sharded engine over
                                   # the available devices (all methods)
    stream: bool = False           # generate colocation chunk-by-chunk
                                   # inside the compiled replay (O(chunk·M)
                                   # schedule memory) instead of scanning
                                   # the materialized [T, M] tensors;
                                   # results are bitwise-identical
    stream_chunk: int = 0          # steps per streamed chunk (0 = auto:
                                   # eval_every when evals run, else 64)
    rebucket_every: int = 0        # distributed runs: drift-check cadence of
                                   # mid-run re-bucketing (0 = off; must be a
                                   # multiple of the streamed chunk length)
    rebucket_threshold: float = 0.25   # drift fraction that triggers a swap


# ---------------------------------------------------------------------------
# data assembly
# ---------------------------------------------------------------------------


def _pad_to(idx_list: List[np.ndarray], rng) -> np.ndarray:
    n = max(len(i) for i in idx_list)
    out = []
    for i in idx_list:
        if len(i) < n:
            i = np.concatenate([i, rng.choice(i, n - len(i))])
        out.append(i)
    return np.stack(out)


def _image_data_fixed(cfg: ExperimentConfig):
    """Per-fixed-device train/test arrays for the Table-1 setting."""
    x, sup, sub = make_image_dataset(cfg.seed, cfg.n_per_sub, cfg.n_super,
                                     cfg.n_sub, cfg.image_size, cfg.noise)
    rng = np.random.default_rng(cfg.seed)
    if cfg.dist == "iid":
        parts = iid_partition(sup, cfg.n_fixed, cfg.seed)
    elif cfg.dist.startswith("dir"):
        parts = dirichlet_partition(sup, cfg.n_fixed, float(cfg.dist[3:]),
                                    cfg.seed, min_per_part=24)
    elif cfg.dist == "shards":
        n_areas = max(-(-cfg.n_fixed // 4), 2)     # ceil, 4 spaces per area
        sh = shards_partition(sup, sub, n_areas=n_areas, seed=cfg.seed)
        parts = [np.concatenate([sh["space_idx"][(a, s)],
                                 sh["general_idx"][(a, s)]])
                 for a in range(n_areas) for s in range(4)]
    else:
        raise ValueError(cfg.dist)
    tr, te = zip(*[train_test_split(p, 0.2, cfg.seed) for p in parts])
    tr = [t[: cfg.train_per_device] for t in tr]
    tr, te = _pad_to(list(tr), rng), _pad_to(list(te), rng)
    return (jnp.asarray(x[tr]), jnp.asarray(sup[tr]),
            jnp.asarray(x[te]), jnp.asarray(sup[te]))


def _image_data_mobile(cfg: ExperimentConfig, mule_space: np.ndarray,
                       mule_area: np.ndarray):
    """Shards data on mules per Sec 4.3.1: space's sub-class + 5th sub-class."""
    x, sup, sub = make_image_dataset(cfg.seed, cfg.n_per_sub, cfg.n_super,
                                     cfg.n_sub, cfg.image_size, cfg.noise)
    # ceil so every place id's area (place // 4) has a partition, min 2 to
    # keep the pre-registry hardcoded layout for small populations
    n_areas = max(-(-cfg.n_fixed // 4), 2)
    sh = shards_partition(sup, sub, n_areas=n_areas, seed=cfg.seed)
    rng = np.random.default_rng(cfg.seed + 1)
    tr_list = []
    for m in range(cfg.n_mules):
        key = (int(mule_area[m]), int(mule_space[m]))
        local = sh["space_idx"][key]
        general = sh["general_idx"][key]
        cap = max(cfg.train_per_device // 2, 8)
        take = rng.choice(local, min(len(local), cap), replace=False)
        takeg = rng.choice(general, min(len(general), cap), replace=False)
        tr_list.append(np.concatenate([take, takeg]))
    tr = _pad_to(tr_list, rng)
    # per-space test sets (mule evaluated on its current space's data)
    te_idx = _pad_to([sh["space_idx"][(a, s)] for a in range(n_areas)
                      for s in range(4)], rng)
    return (jnp.asarray(x[tr]), jnp.asarray(sup[tr]),
            jnp.asarray(x[te_idx]), jnp.asarray(sup[te_idx]))


def _har_data_mobile(cfg: ExperimentConfig, mule_space, mule_area):
    """IMU data per location; spaces map to EgoExo4D-like locations."""
    x, y, loc = make_imu_dataset(cfg.seed, n_per_cell=cfg.n_per_sub)
    rng = np.random.default_rng(cfg.seed + 2)
    space_loc = rng.permutation(8)          # each space -> a location
    tr_list = []
    for m in range(cfg.n_mules):
        sl = space_loc[int(mule_area[m]) * 4 + int(mule_space[m])]
        idx = np.where(loc == sl)[0]
        tr_list.append(rng.choice(idx, min(len(idx), 120), replace=False))
    tr = _pad_to(tr_list, rng)
    te_idx = _pad_to([np.where(loc == space_loc[f])[0][:60] for f in range(8)],
                     rng)
    return (jnp.asarray(x[tr]), jnp.asarray(y[tr]),
            jnp.asarray(x[te_idx]), jnp.asarray(y[te_idx]))


# ---------------------------------------------------------------------------
# model / train / eval
# ---------------------------------------------------------------------------


def _model_fns(cfg: ExperimentConfig):
    if cfg.task == "image":
        mc = CNNConfig(image_size=cfg.image_size, conv_features=(8, 16),
                       hidden=64, n_classes=cfg.n_super)
        init = lambda k: init_cnn(k, mc)
        fwd = cnn_forward
    else:
        mc = LSTMCNNConfig(conv_features=(16, 32), lstm_hidden=32, n_classes=4)
        init = lambda k: init_lstm_cnn(k, mc)
        fwd = lstm_cnn_forward

    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: xent_loss(fwd(p, xb), yb))(params)
        return jax.tree.map(lambda p, gg: p - cfg.lr * gg, params, g)

    def eval_fn(params, xd, yd):
        return accuracy(fwd(params, xd), yd)

    return init, train_fn, eval_fn


def _sample_batches(key, X, Y, batch):
    """X: [P, N, ...] -> random [P, B, ...] minibatches."""
    p, n = X.shape[0], X.shape[1]
    idx = jax.random.randint(key, (p, batch), 0, n)
    xb = jnp.take_along_axis(X, idx.reshape((p, batch) + (1,) * (X.ndim - 2)),
                             axis=1)
    yb = jnp.take_along_axis(Y, idx, axis=1)
    return xb, yb


def _make_pretrain(train_fn, cfg: "ExperimentConfig", n_clients: int,
                   Xtr=None, Ytr=None):
    """Per-device local pretraining as one ``lax.scan`` over pretrain_steps.

    Preserves the former Python loop's ``split(key, 3)`` chain bitwise.
    With ``Xtr/Ytr`` bound the result is ``(models, key) -> models``;
    without, it is ``(models, key, Xtr, Ytr) -> models`` — the
    data-as-argument form ``run_sweep_experiment`` vmaps over seeds.
    """
    def pretrain(models, key, X, Y):
        def body(carry, _):
            models, key = carry
            key, kb, kt = jax.random.split(key, 3)
            batches = _sample_batches(kb, X, Y, cfg.batch)
            keys = jax.random.split(kt, n_clients)
            models = jax.vmap(train_fn)(models, batches, keys)
            return (models, key), None

        (models, _), _ = jax.lax.scan(body, (models, key), None,
                                      length=cfg.pretrain_steps)
        return models

    if Xtr is None:
        return pretrain
    return lambda models, key: pretrain(models, key, Xtr, Ytr)


# ---------------------------------------------------------------------------
# mobility stream
# ---------------------------------------------------------------------------


def _mobility_tensors(cfg: ExperimentConfig):
    """Precomputed co-location schedule (see repro.scenarios.registry).

    Returns (colocation dict with fixed_id/exchange [T, M], pos [T, M, 2],
    area [M]; plus init_space/init_area), mule_space [M], mule_area [M].
    """
    if cfg.scenario:
        co = get_scenario(cfg.scenario).colocation(cfg.seed, cfg.n_mules,
                                                   cfg.steps)
    elif cfg.pattern == "4q":
        visits = synth_foursquare_trace(cfg.seed, n_users=cfg.n_mules,
                                        n_places=8, n_steps=cfg.steps)
        co = trace_colocation(visits, cfg.n_mules, cfg.steps)
    else:
        co = walk_colocation(cfg.seed, cfg.n_mules, cfg.steps,
                             p_cross=float(cfg.pattern))
    return co, co["init_space"], co["init_area"]


# ---------------------------------------------------------------------------
# main experiment driver
# ---------------------------------------------------------------------------


def _mule_mesh(n_mules: int):
    """(1, k) pod x data mesh over the largest divisor of n_mules that the
    device pool covers — the forced-host-device lane of ``--distributed``.

    Prints the mesh it settled on: the population must divide the data
    axis, so a prime ``n_mules`` (or a single-accelerator host, where the
    host-device forcing doesn't apply) degrades to k=1 — still the
    distributed code path, but with nothing actually sharded.
    """
    n_dev = jax.device_count()
    if jax.process_count() > 1:
        # a multi-process mesh must span every process's devices (a rank
        # with no mesh slot would never join the collectives), so the
        # divisor search can't shrink the pool — the population has to fit
        if n_mules % n_dev:
            raise ValueError(
                f"multi-process run: n_mules={n_mules} must divide over "
                f"all {n_dev} devices ({jax.process_count()} processes x "
                f"{jax.local_device_count()} local)")
        k = n_dev
    else:
        k = max(s for s in range(1, min(n_dev, n_mules) + 1)
                if n_mules % s == 0)
    print(f"distributed mesh: 1 pod x {k} mule shards "
          f"({n_dev} devices visible, n_mules={n_mules})"
          + (" — WARNING: k=1 shards nothing" if k == 1 else ""))
    return jax.sharding.Mesh(
        np.array(jax.devices()[:k]).reshape(1, k), ("pod", "data"))


def run_experiment(cfg: ExperimentConfig) -> Dict:
    t_start = time.time()
    if cfg.scenario:
        spec = get_scenario(cfg.scenario)
        cfg = dataclasses.replace(cfg, mode=spec.mode, dist=spec.dist,
                                  task=spec.task, n_fixed=spec.n_fixed)
    init, train_fn, eval_fn = _model_fns(cfg)
    colocation, mule_space, mule_area = _mobility_tensors(cfg)

    if cfg.mode == "fixed":
        Xtr, Ytr, Xte, Yte = _image_data_fixed(cfg)
        n_clients = cfg.n_fixed
    else:
        if cfg.task == "image":
            Xtr, Ytr, Xte, Yte = _image_data_mobile(cfg, mule_space, mule_area)
        else:
            Xtr, Ytr, Xte, Yte = _har_data_mobile(cfg, mule_space, mule_area)
        n_clients = cfg.n_mules

    key = jax.random.PRNGKey(cfg.seed + 100)
    eval_v = jax.jit(jax.vmap(eval_fn))

    # -- per-device local pretraining (paper Sec 4.2.1 / 4.3.1) --------------
    # one compiled lax.scan over pretrain_steps (was: one jitted dispatch per
    # step x pretrain_steps), preserving the split(key, 3) chain bitwise
    pretrain = _make_pretrain(train_fn, cfg, n_clients, Xtr, Ytr)
    pre_models = jax.jit(pretrain)(jax.vmap(init)(
        jax.random.split(jax.random.PRNGKey(cfg.seed), n_clients)),
        jax.random.PRNGKey(cfg.seed + 7))

    def eval_fixed_models(models):
        """Evaluate stacked fixed-device models on their space test sets."""
        return np.asarray(eval_v(models, Xte, Yte))

    def eval_mobile_models(models, cur_fid):
        """Each mule evaluated on the test set of its current/last space."""
        fid = np.asarray(cur_fid).clip(0)
        Xm = Xte[fid]
        Ym = Yte[fid]
        return np.asarray(eval_v(models, Xm, Ym))

    traces = []
    sizes = jnp.full((n_clients,), float(Xtr.shape[1]))

    # ---------------- federated baselines (round-based, no mobility) --------
    if cfg.method in ("fedavg", "cfl", "fedas"):
        from repro.core.aggregation import weighted_average
        n_rounds = cfg.steps // 10
        model = weighted_average(pre_models, sizes)
        if cfg.method == "cfl":
            st = CFLState(clusters=[np.arange(n_clients)], models=[model],
                          eps1=0.5, eps2=0.05)
        if cfg.method == "fedas":
            clients = pre_models
        for r in range(n_rounds):
            key, kb, kr = jax.random.split(key, 3)
            batches = _sample_batches(kb, Xtr, Ytr, cfg.batch)
            if cfg.method == "fedavg":
                model = fedavg_round(model, batches, sizes, train_fn, kr,
                                     local_steps=2)
                stacked = jax.tree.map(
                    lambda l: jnp.broadcast_to(l[None], (n_clients,) + l.shape),
                    model)
            elif cfg.method == "cfl":
                st = cfl_round(st, batches, sizes, train_fn, kr, local_steps=2)
                stacked = cfl_client_models(st, n_clients)
            else:
                model, clients = fedas_round(model, clients, batches, sizes,
                                             train_fn, kr)
                stacked = clients
            if (r + 1) % max(cfg.eval_every // 10, 1) == 0:
                acc = eval_fixed_models(stacked) if cfg.mode == "fixed" else \
                    eval_mobile_models(stacked, np.arange(n_clients) % cfg.n_fixed)
                # log the post-step index (round r covers steps
                # [r*10, (r+1)*10)), matching the mobility methods' x-axis
                traces.append(((r + 1) * 10 - 1, float(acc.mean())))
        final_models = stacked

    # ---------------- mobility-coupled methods (all on the scan engine) ------
    else:
        fresh = (FreshnessConfig(init_threshold=1e9, warmup=10**9)
                 if cfg.freshness_off else FreshnessConfig())
        pcfg = PopulationConfig(
            mode=cfg.mode, n_fixed=cfg.n_fixed, n_mules=cfg.n_mules,
            gamma=cfg.gamma, freshness=fresh)
        pop = init_population(jax.random.PRNGKey(cfg.seed), init, pcfg)
        if cfg.mode == "fixed":
            # fixed devices hold the pretrained models; each mule starts with
            # a snapshot from its initial space (its user's 'home' space)
            pop["fixed_models"] = pre_models
            home = jnp.asarray(mule_area * 4 + mule_space, jnp.int32)
            pop["mule_models"] = jax.tree.map(lambda l: l[home], pre_models)
        else:
            pop["mule_models"] = pre_models

        def batch_fn(kb, t):
            sampled = _sample_batches(kb, Xtr, Ytr, cfg.batch)
            if cfg.mode == "fixed":
                return {"fixed": sampled, "mule": None}
            return {"fixed": None, "mule": sampled}

        if cfg.mode == "fixed":
            eval_hook = lambda st, last: eval_v(st["fixed_models"], Xte, Yte)
        else:
            eval_hook = lambda st, last: eval_v(st["mule_models"],
                                                Xte[last], Yte[last])

        # all mobility methods draw per-step keys as fold_in(ke, t) — the
        # engine's documented discipline — so at a fixed seed every method
        # trains on identical batch draws and curves differ only by method;
        # the whole schedule (method dispatch, t%3 cadences, in-scan eval)
        # is one compiled program. The input population is not read again,
        # so its buffers are donated and the replay updates in place.
        key, ke = jax.random.split(key)
        generator = None
        if cfg.stream:
            # streamed replay: the schedule is generated chunk-by-chunk
            # inside the compiled scan. Scenarios with a native generator
            # stream procedurally; everything else streams the compacted
            # form of the colocation already built for the data partition.
            generator = (scenario_generator(cfg.scenario, cfg.seed,
                                            cfg.n_mules, cfg.steps,
                                            colocation=colocation)
                         if cfg.scenario else compact_colocation(colocation))
        if cfg.distributed:
            # mule-sharded replay: every method lowers through the one
            # MethodProgram table (the peer baselines ring their encounter
            # search around the mesh). In mobile mode the in-scan eval hook
            # would read sharded mule models shard-locally, so evaluation
            # happens once on the gathered final state instead.
            from repro.core.distributed import (DistributedConfig,
                                                to_distributed_state)
            from repro.scenarios import run_population_distributed
            dcfg = DistributedConfig(pop=pcfg,
                                     rebucket_every=cfg.rebucket_every,
                                     rebucket_threshold=cfg.rebucket_threshold)
            mesh = _mule_mesh(cfg.n_mules)
            dist_eval = cfg.mode == "fixed"
            if cfg.stream:
                chunk = cfg.stream_chunk or (
                    cfg.rebucket_every or
                    (cfg.eval_every if dist_eval else 64))
                pop, aux = run_population_streamed(
                    to_distributed_state(pop, dcfg), generator, batch_fn,
                    train_fn, pcfg, ke, n_steps=cfg.steps, chunk_len=chunk,
                    eval_every=cfg.eval_every if dist_eval else None,
                    eval_fn=eval_hook if dist_eval else None,
                    method=cfg.method, donate=True, mesh=mesh, dcfg=dcfg)
            else:
                pop, aux = run_population_distributed(
                    to_distributed_state(pop, dcfg), colocation, batch_fn,
                    train_fn, dcfg, mesh, ke,
                    eval_every=cfg.eval_every if dist_eval else None,
                    eval_fn=eval_hook if dist_eval else None,
                    method=cfg.method, donate=True)
            if jax.process_count() > 1:
                # multi-process cluster: the metrics below np-read and
                # fancy-index the final state, which multi-host arrays
                # refuse — pull every leaf back to host numpy (sharded
                # leaves allgather their row blocks, replicated leaves
                # read the local replica)
                from repro.launch.multiprocess import gather_global
                pop = jax.tree.map(gather_global, pop)
                aux = jax.tree.map(gather_global, aux)
        elif cfg.stream:
            pop, aux = run_population_streamed(
                pop, generator, batch_fn, train_fn, pcfg, ke,
                n_steps=cfg.steps,
                chunk_len=cfg.stream_chunk or cfg.eval_every,
                eval_every=cfg.eval_every, eval_fn=eval_hook,
                method=cfg.method, donate=True)
        else:
            pop, aux = run_population(pop, colocation, batch_fn, train_fn,
                                      pcfg, ke, eval_every=cfg.eval_every,
                                      eval_fn=eval_hook, method=cfg.method,
                                      donate=True)
        traces = ([] if aux["evals"] is None else
                  [(int(s), float(np.mean(a))) for s, a in
                   zip(aux["eval_steps"], np.asarray(aux["evals"]))])
        last_fid = aux["last_fid"]
        final_models = (pop["fixed_models"] if cfg.mode == "fixed"
                        else pop["mule_models"])

    # ---------------- final metrics (pre/post local) --------------------------
    if cfg.mode == "fixed":
        pre = eval_fixed_models(final_models)
        post_models = final_models
        for _ in range(cfg.post_local_epochs):
            key, kb, kt = jax.random.split(key, 3)
            batches = _sample_batches(kb, Xtr, Ytr, cfg.batch)
            keys = jax.random.split(kt, n_clients)
            post_models = jax.vmap(train_fn)(post_models, batches, keys)
        post = eval_fixed_models(post_models)
    else:
        pre = eval_mobile_models(final_models, last_fid if cfg.method not in
                                 ("fedavg", "cfl", "fedas") else
                                 np.arange(n_clients) % cfg.n_fixed)
        post = pre

    return {
        "config": dataclasses.asdict(cfg),
        "trace": traces,
        "pre_local_acc": float(np.mean(pre)),
        "post_local_acc": float(np.mean(post)),
        "wall_s": time.time() - t_start,
    }


# ---------------------------------------------------------------------------
# batched multi-seed sweeps
# ---------------------------------------------------------------------------


def _stack_wrap_pad(arrs: List[np.ndarray]) -> jnp.ndarray:
    """Stack per-seed [P, N, ...] arrays whose N varies across seeds.

    Shorter pools are padded to the longest with uniformly-drawn repeats
    (fixed rng, mirroring ``_pad_to``), so no sample is *systematically*
    over-weighted; any individual repeat still tilts that seed's empirical
    sampling/eval weights slightly, which is why per-seed sweep metrics
    can differ from an unpadded ``run_experiment`` at the same seed.
    """
    rng = np.random.default_rng(0)
    n = max(a.shape[1] for a in arrs)
    out = []
    for a in arrs:
        a = np.asarray(a)
        idx = np.concatenate([np.arange(a.shape[1]),
                              rng.integers(0, a.shape[1], n - a.shape[1])])
        out.append(a[:, idx])
    return jnp.asarray(np.stack(out))


def run_sweep_experiment(cfg: ExperimentConfig, seeds: Sequence[int],
                         methods: Optional[Sequence[str]] = None) -> Dict:
    """Seed-averaged multi-method sweep on the batched scan engine.

    Builds per-seed datasets, mobility schedules, and pretrained
    populations, stacks them on a leading seed axis, and replays every
    requested method with ``run_sweep`` — one vmapped compiled program per
    method instead of ``len(seeds) x len(methods)`` retraced runs. The
    federated baselines (fedavg/cfl/fedas) are round-based and not on the
    engine; request those through ``run_experiment``.

    Returns per-method seed-stacked and seed-averaged accuracy curves on
    the shared post-step x-axis (``eval_steps``).
    """
    t_start = time.time()
    methods = list(methods or [cfg.method])
    bad = [m for m in methods if m not in METHODS_MOBILE]
    if bad:
        raise ValueError(f"not engine methods: {bad}; pick from "
                         f"{METHODS_MOBILE}")
    if cfg.scenario:
        spec = get_scenario(cfg.scenario)
        cfg = dataclasses.replace(cfg, mode=spec.mode, dist=spec.dist,
                                  task=spec.task, n_fixed=spec.n_fixed)
    init, train_fn, eval_fn = _model_fns(cfg)
    n_clients = cfg.n_fixed if cfg.mode == "fixed" else cfg.n_mules

    # -- per-seed assembly (numpy-level), stacked on a leading [S] axis ------
    cos, homes, inits, pre_keys, run_keys = [], [], [], [], []
    Xtr_l, Ytr_l, Xte_l, Yte_l = [], [], [], []
    for s in seeds:
        scfg = dataclasses.replace(cfg, seed=int(s))
        co, mule_space, mule_area = _mobility_tensors(scfg)
        if cfg.mode == "fixed":
            Xtr, Ytr, Xte, Yte = _image_data_fixed(scfg)
        elif cfg.task == "image":
            Xtr, Ytr, Xte, Yte = _image_data_mobile(scfg, mule_space,
                                                    mule_area)
        else:
            Xtr, Ytr, Xte, Yte = _har_data_mobile(scfg, mule_space, mule_area)
        cos.append(co)
        homes.append(jnp.asarray(mule_area * 4 + mule_space, jnp.int32))
        inits.append(jax.vmap(init)(
            jax.random.split(jax.random.PRNGKey(int(s)), n_clients)))
        pre_keys.append(jax.random.PRNGKey(int(s) + 7))
        # same chain run_experiment uses: ke = split(PRNGKey(seed + 100))[1]
        run_keys.append(jax.random.split(
            jax.random.PRNGKey(int(s) + 100))[1])
        Xtr_l.append(Xtr)
        Ytr_l.append(Ytr)
        Xte_l.append(Xte)
        Yte_l.append(Yte)
    context = (_stack_wrap_pad(Xtr_l), _stack_wrap_pad(Ytr_l),
               _stack_wrap_pad(Xte_l), _stack_wrap_pad(Yte_l))

    # -- vmapped pretraining: one compiled scan for all seeds ----------------
    pretrain = _make_pretrain(train_fn, cfg, n_clients)
    pre_models = jax.jit(jax.vmap(pretrain))(
        stack_trees(inits), stack_trees(pre_keys), context[0], context[1])

    fresh = (FreshnessConfig(init_threshold=1e9, warmup=10**9)
             if cfg.freshness_off else FreshnessConfig())
    pcfg = PopulationConfig(mode=cfg.mode, n_fixed=cfg.n_fixed,
                            n_mules=cfg.n_mules, gamma=cfg.gamma,
                            freshness=fresh)
    pops = stack_trees([init_population(jax.random.PRNGKey(int(s)), init,
                                        pcfg) for s in seeds])
    if cfg.mode == "fixed":
        pops["fixed_models"] = pre_models
        pops["mule_models"] = jax.vmap(
            lambda pre, home: jax.tree.map(lambda l: l[home], pre))(
                pre_models, stack_trees(homes))
    else:
        pops["mule_models"] = pre_models

    def batch_fn(kb, t, ctx):
        sampled = _sample_batches(kb, ctx[0], ctx[1], cfg.batch)
        if cfg.mode == "fixed":
            return {"fixed": sampled, "mule": None}
        return {"fixed": None, "mule": sampled}

    if cfg.mode == "fixed":
        eval_hook = lambda st, last, ctx: jax.vmap(eval_fn)(
            st["fixed_models"], ctx[2], ctx[3])
    else:
        eval_hook = lambda st, last, ctx: jax.vmap(eval_fn)(
            st["mule_models"], ctx[2][last], ctx[3][last])

    out = run_sweep(pops, stack_colocations(cos), batch_fn, train_fn, pcfg,
                    stack_trees(run_keys), eval_every=cfg.eval_every,
                    eval_fn=eval_hook, methods=tuple(methods),
                    context=context)

    final_eval = jax.jit(jax.vmap(eval_hook))
    result_methods, eval_steps = {}, np.zeros((0,), int)
    for m, (final, aux) in out.items():
        eval_steps = aux["eval_steps"]
        acc = (np.asarray(aux["evals"]).mean(axis=-1)
               if aux["evals"] is not None
               else np.zeros((len(list(seeds)), 0)))     # [S, E]
        facc = np.asarray(final_eval(final, aux["last_fid"],
                                     context)).mean(axis=-1)  # [S]
        result_methods[m] = {
            "acc": acc.tolist(),
            "mean_acc": acc.mean(axis=0).tolist(),
            "final_acc": facc.tolist(),
            "mean_final_acc": float(facc.mean()),
        }

    return {
        "config": dataclasses.asdict(cfg),
        "seeds": [int(s) for s in seeds],
        "eval_steps": [int(x) for x in eval_steps],
        "methods": result_methods,
        "wall_s": time.time() - t_start,
    }
