"""Fig 10/11 analogue: per-phase timing of one In-House cycle.

The paper measures discover (5.07 s) / send (0.007 s) / fixed-device
aggregate+train (2.07 s) / receive (0.007 s) on Jetson+Pi over ad-hoc WiFi.
Here the same protocol phases are timed as JAX ops on this host: discovery =
one mobility step; send/receive = model serialization size over the paper's
measured ~60 MB/s effective link; aggregate+train = the actual jitted ops.
Derived column reports the paper-comparable per-phase seconds.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mule_cnn import CNNConfig
from repro.core.aggregation import masked_group_mean, pairwise_mix
from repro.mobility import MobilityConfig, init_mobility, mobility_step
from repro.models.cnn import cnn_forward, init_cnn, xent_loss

LINK_BYTES_PER_S = 60e6   # effective ad-hoc WiFi throughput implied by Fig 10


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run():
    cfg = CNNConfig()  # the paper's full CNN (32x32, 20 classes)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    n_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    mcfg = MobilityConfig()
    mob = init_mobility(jax.random.PRNGKey(1), mcfg)

    x = jax.random.normal(jax.random.PRNGKey(2), (16, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(3), (16,), 0, 20)

    discover = jax.jit(lambda s: mobility_step(s, mcfg)[0])
    t_discover = _time(discover, mob)

    stacked = jax.tree.map(lambda l: jnp.stack([l] * 4), params)
    assign = jnp.ones((1, 4)) / 4

    agg = jax.jit(lambda m, a: masked_group_mean(m, a)[0])
    t_agg = _time(agg, stacked, assign)

    def train(p):
        g = jax.grad(lambda q: xent_loss(cnn_forward(q, x), y))(p)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    t_train = _time(jax.jit(train), params)
    t_mix = _time(jax.jit(lambda a, b: pairwise_mix(a, b, 0.5)), params, params)
    t_link = n_bytes / LINK_BYTES_PER_S

    rows = [
        ("proto.discover_step", t_discover * 1e6, "paper: 5.07s radio discovery"),
        ("proto.send_model", t_link * 1e6, f"{n_bytes/1e6:.2f}MB @60MB/s "
                                           f"(paper: 0.007s)"),
        ("proto.aggregate", t_agg * 1e6, "4-mule dwell-weighted mean"),
        ("proto.train_1step", t_train * 1e6, "paper in-house train: 2.07s"),
        ("proto.mix_back", t_mix * 1e6, "mule-side aggregate"),
        ("proto.recv_model", t_link * 1e6, "paper: 0.007s"),
    ]
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
