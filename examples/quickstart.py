"""Quickstart: a 60-second ML Mule simulation.

Eight smart-space fixed devices, twelve phone "mules", the paper's CNN on a
procedural image task. Watch per-space accuracy improve as mules ferry model
snapshots between spaces — no server, no always-on connectivity.

  PYTHONPATH=src python examples/quickstart.py

Scenarios
---------
Mobility, protocol mode, and data partition are bundled behind string names
in the scenario registry; the whole run is one compiled ``lax.scan``:

    from repro.scenarios import SCENARIOS, get_scenario, run_population

    spec = get_scenario("random_walk")      # or: commuter, foursquare_sparse,
                                            #     shift_worker, event_crowd
    co = spec.colocation(seed=1, n_mules=12, n_steps=240)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                eval_every=60, eval_fn=eval_hook)

New workloads are one ``repro.scenarios.register(...)`` entry, and
``examples/run_scenario.py --scenario <name>`` replays any of them
end-to-end against the paper's harness.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mule_cnn import CNNConfig
from repro.core import PopulationConfig, init_population
from repro.data import dirichlet_partition, make_image_dataset
from repro.data.partition import train_test_split
from repro.models.cnn import accuracy, cnn_forward, init_cnn, xent_loss
from repro.scenarios import get_scenario, run_population

F, M, STEPS = 8, 12, 240

# --- data: 20 super-classes, Dirichlet(0.01) over 8 spaces ------------------
x, sup, _ = make_image_dataset(0, n_per_sub=16, n_super=20, size=16, noise=3.0)
parts = dirichlet_partition(sup, F, alpha=0.01, seed=0, min_per_part=24)
rng = np.random.default_rng(0)
tr, te = zip(*[train_test_split(p, 0.2, 0) for p in parts])
n_tr = min(32, min(len(t) for t in tr))
n_te = min(len(t) for t in te)
Xtr = jnp.asarray(np.stack([x[t[:n_tr]] for t in tr]))
Ytr = jnp.asarray(np.stack([sup[t[:n_tr]] for t in tr]))
Xte = jnp.asarray(np.stack([x[t[:n_te]] for t in te]))
Yte = jnp.asarray(np.stack([sup[t[:n_te]] for t in te]))

# --- model + protocol ---------------------------------------------------------
mc = CNNConfig(image_size=16, conv_features=(8, 16), hidden=64, n_classes=20)


def train_fn(params, batch, key):
    xb, yb = batch
    g = jax.grad(lambda p: xent_loss(cnn_forward(p, xb), yb))(params)
    return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)


def batch_fn(key, t):
    idx = jax.random.randint(key, (F, 16), 0, Xtr.shape[1])
    return {"fixed": (jnp.take_along_axis(Xtr, idx[:, :, None, None, None], 1),
                      jnp.take_along_axis(Ytr, idx, 1)), "mule": None}


pcfg = PopulationConfig(mode="fixed", n_fixed=F, n_mules=M)
pop = init_population(jax.random.PRNGKey(0), lambda k: init_cnn(k, mc), pcfg)

# --- one compiled scan over the whole scenario --------------------------------
co = get_scenario("random_walk").colocation(1, M, STEPS)
eval_v = jax.vmap(lambda p, xd, yd: accuracy(cnn_forward(p, xd), yd))
pop, aux = run_population(
    pop, co, batch_fn, train_fn, pcfg, jax.random.PRNGKey(42),
    eval_every=60, eval_fn=lambda st, last: eval_v(st["fixed_models"], Xte, Yte))

for t, acc in zip(aux["eval_steps"], np.asarray(aux["evals"])):
    print(f"step {t+1:4d}  per-space acc: {np.round(acc, 2)}  "
          f"mean {acc.mean():.3f}")
print("done — models evolved purely through mule-carried snapshots.")
