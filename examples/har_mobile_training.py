"""Mobile-device training scenario (paper Fig. 2b) on IMU HAR.

Phones collect accelerometer/gyro windows as their users move through
spaces; fixed devices only host/aggregate. Compares ML Mule vs Gossip vs
Local over time (Fig. 8/9 analogue).

  PYTHONPATH=src python examples/har_mobile_training.py [--p-cross 0.1]
"""
import argparse

from benchmarks.common import ExperimentConfig, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--p-cross", default="0.1")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"HAR (LSTM-CNN over IMU windows), P_cross={args.p_cross}")
    for method in ("local", "gossip", "mlmule"):
        cfg = ExperimentConfig(task="har", mode="mobile", method=method,
                               pattern=args.p_cross, steps=args.steps,
                               seed=args.seed, batch=12, lr=0.03)
        r = run_experiment(cfg)
        trace = " ".join(f"{t}:{a:.2f}" for t, a in r["trace"])
        print(f"{method:8s} final={r['pre_local_acc']:.3f}  trace: {trace}")


if __name__ == "__main__":
    main()
