"""End-to-end driver: ML Mule over a population of language models.

This is the framework's "big model" path: each fixed device hosts a
transformer LM (selectable with --arch from the 10 assigned architectures,
reduced config on CPU) trained on space-specific token streams; mules carry
LM snapshots between spaces. Demonstrates that the protocol layer is
model-agnostic — the same population engine that moves CNNs moves sharded
transformer pytrees.

  PYTHONPATH=src python examples/train_lm_population.py --arch stablelm-1.6b \
      --steps 60
(full-scale: drop --smoke-implied reduced config by editing ARCH below and
run under the production mesh via repro.launch.train)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import PopulationConfig, init_population, population_step
from repro.core.freshness import FreshnessConfig
from repro.data import make_lm_dataset
from repro.mobility import MobilityConfig, init_mobility, mobility_step
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-fixed", type=int, default=4)
    ap.add_argument("--n-mules", type=int, default=6)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    print(f"population of {args.n_fixed} fixed + {args.n_mules} mule "
          f"{cfg.name} models ({cfg.param_count()/1e6:.2f}M params each)")

    seqs, spaces = make_lm_dataset(0, n_seqs=args.n_fixed * 32,
                                   seq_len=args.seq, vocab=cfg.vocab,
                                   n_spaces=args.n_fixed)
    per_space = [seqs[spaces == f] for f in range(args.n_fixed)]
    n = min(len(p) for p in per_space)
    data = jnp.asarray(np.stack([p[:n] for p in per_space]))  # [F, n, S]

    def train_fn(params, batch, key):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, {"tokens": batch})
        return jax.tree.map(lambda p, g: p - 3e-3 * g, params, grads)

    pcfg = PopulationConfig(mode="fixed", n_fixed=args.n_fixed,
                            n_mules=args.n_mules,
                            freshness=FreshnessConfig())
    pop = init_population(jax.random.PRNGKey(0), model.init, pcfg)
    mcfg = MobilityConfig(n_mules=args.n_mules, n_areas=1, p_cross=0.2)
    mob = init_mobility(jax.random.PRNGKey(1), mcfg)

    @jax.jit
    def eval_loss(params, toks):
        return model.loss(params, {"tokens": toks})[0]

    @jax.jit
    def sim_step(pop, mob, key):
        mob, info = mobility_step(mob, mcfg)
        kb, kt = jax.random.split(key)
        idx = jax.random.randint(kb, (args.n_fixed, args.batch), 0, n)
        batches = {"fixed": jnp.take_along_axis(
            data, idx[:, :, None], axis=1), "mule": None}
        info = {"fixed_id": jnp.clip(info["fixed_id"], -1, args.n_fixed - 1),
                "exchange": info["exchange"]}
        return population_step(pop, info, batches, train_fn, pcfg, kt), mob

    key = jax.random.PRNGKey(42)
    t0 = time.time()
    for t in range(args.steps):
        key, k = jax.random.split(key)
        pop, mob = sim_step(pop, mob, k)
        if (t + 1) % 20 == 0:
            losses = [float(eval_loss(
                jax.tree.map(lambda l, f=f: l[f], pop["fixed_models"]),
                data[f, :args.batch])) for f in range(args.n_fixed)]
            print(f"step {t+1:4d}  per-space LM loss: "
                  f"{np.round(losses, 3)}  ({time.time()-t0:.0f}s)")
    print("done")


if __name__ == "__main__":
    main()
