"""Fixed-device training scenario (paper Fig. 2a): smart-space devices hold
the data and train; mules ferry snapshots. Compares ML Mule against
Local-Only and FedAvg on the same partition and prints the Table-1-style
pre/post-local accuracies.

ML Mule runs through the compiled scan engine (``repro.scenarios``); the
baselines drive the same precomputed co-location tensors step by step.

  PYTHONPATH=src python examples/smart_space_fixed_training.py \
      [--dist dir0.01] [--pattern 0.1] [--steps 240] [--scenario random_walk]
"""
import argparse

from benchmarks.common import ExperimentConfig, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="dir0.01")
    ap.add_argument("--pattern", default="0.1")
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="",
                    help="registry scenario name (overrides dist/pattern)")
    args = ap.parse_args()

    print(f"distribution={args.dist} mobility P_cross={args.pattern}"
          + (f" scenario={args.scenario}" if args.scenario else ""))
    print(f"{'method':10s} {'pre-local':>10s} {'post-local':>11s} {'wall':>7s}")
    for method in ("local", "fedavg", "mlmule"):
        cfg = ExperimentConfig(mode="fixed", method=method, dist=args.dist,
                               pattern=args.pattern, steps=args.steps,
                               seed=args.seed, scenario=args.scenario)
        r = run_experiment(cfg)
        print(f"{method:10s} {r['pre_local_acc']:10.3f} "
              f"{r['post_local_acc']:11.3f} {r['wall_s']:6.0f}s")


if __name__ == "__main__":
    main()
