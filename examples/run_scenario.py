"""Run any registered scenario end-to-end through the paper's harness.

  PYTHONPATH=src python examples/run_scenario.py --scenario commuter
  PYTHONPATH=src python examples/run_scenario.py --scenario commuter \
      --method gossip --seeds 4          # seed-averaged, one vmapped program
  PYTHONPATH=src python examples/run_scenario.py --scenario commuter \
      --method oppcl --distributed       # mule-sharded over host devices
  PYTHONPATH=src python examples/run_scenario.py --list

The scenario supplies mobility, protocol mode, data partition — and, for
the churn family, a per-step device activity mask the engine threads
through every path: ``commuter_churn`` (Markov join/leave sessions),
``event_crowd_flash`` (flash joins, mass exits), ``multi_area_3city``
(3 near-isolated cities, 12 spaces), ``mixed_cadence`` (per-space
exchange tempos); the ``har_*`` variants bind the LSTM-CNN IMU task. The
harness supplies the model, pretraining, and the compiled scan engine.
Every mobile method (mlmule/gossip/oppcl/local/mlmule+gossip) rides the
engine; with ``--seeds N > 1`` the replay batches all seeds into one
vmapped compiled program (``run_sweep_experiment``); with ``--distributed``
it shards the mule population over a forced host-device mesh instead
(``run_population_distributed`` — one shard_map'd scan, the peer-encounter
baselines ring their neighbor search across shards); with ``--stream`` the
colocation schedule is generated chunk-by-chunk inside the compiled replay
(``run_population_streamed`` — O(chunk*M) schedule memory instead of
O(T*M), bitwise-identical results, composes with ``--distributed``); with
``--processes N`` the whole run re-execs as an N-rank local
``jax.distributed`` cluster (gloo CPU collectives) and the mule mesh
spans every rank's devices — same engines, same results, per-process
state::

  PYTHONPATH=src python examples/run_scenario.py --scenario commuter \\
      --distributed --stream --processes 2 --devices-per-process 4
"""
import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                       # for `benchmarks`
sys.path.insert(0, os.path.join(_ROOT, "src"))  # for `repro`

def _argv_value(flag, default):
    return (sys.argv[sys.argv.index(flag) + 1] if flag in sys.argv
            else default)


# multi-process lane: `--processes N` re-execs this script as an
# N-process local `jax.distributed` cluster. Decided by argv peek for the
# same reason as the device forcing below — the spawn must happen before
# anything imports jax — and skipped inside the spawned children, which
# carry the REPRO_MP_* coordinator triple in their environment instead.
from repro.launch.multiprocess import (initialize_from_env,  # noqa: E402
                                       spawn_local_cluster)

_N_PROC = int(_argv_value("--processes", "1"))
if _N_PROC > 1 and not os.environ.get("REPRO_MP_COORDINATOR"):
    n_dev = int(_argv_value("--devices-per-process", "4"))
    results = spawn_local_cluster(
        [sys.executable] + sys.argv, _N_PROC, n_dev,
        coordinator=_argv_value("--coordinator", None))
    sys.stdout.write(results[0].stdout)
    for pid, res in enumerate(results):
        if res.returncode != 0:
            sys.stderr.write(f"--- rank {pid} failed "
                             f"(exit {res.returncode}) ---\n{res.stdout}\n")
            sys.exit(res.returncode)
    sys.exit(0)

# the host-device mesh must be forced before jax initializes, so peek at
# argv ahead of the real argparse run (which needs jax-importing modules)
if "--distributed" in sys.argv and \
        "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

# inside a spawned rank: bring up jax.distributed before the first jax
# import below initializes the backend (no-op without the env triple)
initialize_from_env()

from benchmarks.common import (METHODS_MOBILE, ExperimentConfig,
                               run_experiment, run_sweep_experiment)
from repro.scenarios import SCENARIOS, list_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="random_walk",
                    choices=list_scenarios(),
                    help="registered scenario; churn variants "
                         "(commuter_churn, event_crowd_flash) replay with "
                         "device join/leave masks, multi_area_3city spans "
                         "3 cities, mixed_cadence varies per-space "
                         "exchange tempo, har_* bind the LSTM-CNN IMU task "
                         "(see --list)")
    ap.add_argument("--method", default="mlmule", choices=METHODS_MOBILE)
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--n-mules", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeds", type=int, default=1,
                    help="sweep seed..seed+N-1 as one vmapped program")
    ap.add_argument("--distributed", action="store_true",
                    help="shard the mule population over the available "
                         "devices (on CPU hosts, 8 forced host devices; "
                         "the run prints the mesh it settles on — "
                         "n-mules must divide the shard count) and "
                         "replay on the distributed scan engine — every "
                         "method (mlmule, gossip, oppcl, local, "
                         "mlmule+gossip) now shards; mobile-mode runs "
                         "report final accuracy only (in-scan eval reads "
                         "sharded state). Mutually exclusive with "
                         "--seeds > 1.")
    ap.add_argument("--stream", action="store_true",
                    help="generate the colocation schedule chunk-by-chunk "
                         "inside the compiled replay instead of "
                         "materializing the full [T, M] tensors up front — "
                         "O(chunk*M) schedule memory instead of O(T*M), "
                         "bitwise-identical results (run_population_"
                         "streamed; composes with --distributed). "
                         "Mutually exclusive with --seeds > 1.")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="chunk length for --stream (0 = engine default; "
                         "must be a multiple of the eval cadence)")
    ap.add_argument("--rebucket-every", type=int, default=0,
                    help="distributed runs: re-check the shard/area "
                         "alignment every N steps and re-bucket the mule "
                         "population when the drift fraction crosses "
                         "--rebucket-threshold (0 = off; must be a "
                         "multiple of --stream-chunk so swaps land on "
                         "chunk boundaries). Keeps the ring's hop pruning "
                         "effective on migratory scenarios "
                         "(multi_area_migratory).")
    ap.add_argument("--rebucket-threshold", type=float, default=0.25,
                    help="drifted-mule fraction that triggers a re-bucket "
                         "swap (see --rebucket-every)")
    ap.add_argument("--processes", type=int, default=1,
                    help="re-exec this run as an N-process local "
                         "jax.distributed cluster (requires --distributed; "
                         "the mule mesh then spans every process's devices "
                         "and n-mules must divide processes x "
                         "devices-per-process; composes with --stream and "
                         "--rebucket-every)")
    ap.add_argument("--devices-per-process", type=int, default=4,
                    help="forced host devices per rank for --processes")
    ap.add_argument("--coordinator", default=None, metavar="ADDR",
                    help="host:port for the jax.distributed coordinator "
                         "(default: a free local port)")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    args = ap.parse_args()

    if args.list:
        for name in list_scenarios():
            print(f"{name:18s} {SCENARIOS[name].description}")
        return

    if args.processes > 1 and not args.distributed:
        ap.error("--processes shards the population across a cluster; "
                 "add --distributed")
    if args.distributed and args.seeds > 1:
        ap.error("--distributed runs one seed; drop --seeds")
    if args.stream and args.seeds > 1:
        ap.error("--stream runs one seed; drop --seeds")
    if args.rebucket_every:
        if not args.distributed:
            ap.error("--rebucket-every re-buckets the sharded population; "
                     "add --distributed")
        if args.stream_chunk and args.rebucket_every % args.stream_chunk:
            # validated here, before any device work: a misaligned cadence
            # would otherwise only surface once the engine builds chunks
            raise ValueError(
                f"--rebucket-every={args.rebucket_every} must be a "
                f"multiple of --stream-chunk={args.stream_chunk} so "
                "re-bucketing lands on chunk boundaries")

    spec = SCENARIOS[args.scenario]
    print(f"scenario={spec.name} mode={spec.mode} dist={spec.dist} "
          f"task={spec.task} method={args.method}"
          + (" [distributed]" if args.distributed else "")
          + (" [streamed]" if args.stream else "")
          + (f" [{args.processes} processes]" if args.processes > 1
             else ""))
    cfg = ExperimentConfig(scenario=args.scenario, method=args.method,
                           steps=args.steps, n_mules=args.n_mules,
                           seed=args.seed, distributed=args.distributed,
                           stream=args.stream,
                           stream_chunk=args.stream_chunk,
                           rebucket_every=args.rebucket_every,
                           rebucket_threshold=args.rebucket_threshold)

    if args.seeds > 1:
        seeds = range(args.seed, args.seed + args.seeds)
        r = run_sweep_experiment(cfg, seeds)
        d = r["methods"][args.method]
        import numpy as np
        spread = np.asarray(d["acc"]).std(axis=0)
        for t, acc, sd in zip(r["eval_steps"], d["mean_acc"], spread):
            print(f"  step {t+1:4d}  mean acc {acc:.3f} +/- {sd:.3f} "
                  f"({args.seeds} seeds)")
        print(f"final pre-local acc {d['mean_final_acc']:.3f}  "
              f"wall {r['wall_s']:.0f}s")
        return

    r = run_experiment(cfg)
    for t, acc in r["trace"]:
        print(f"  step {t+1:4d}  mean acc {acc:.3f}")
    print(f"final pre-local acc {r['pre_local_acc']:.3f}  "
          f"wall {r['wall_s']:.0f}s")


if __name__ == "__main__":
    main()
