"""Distributed scan engine on an in-process single-device mesh.

Multi-device coverage of the same properties lives in
``tests/test_distributed.py`` (slow, subprocess); these run in tier-1 and
pin the engine's contracts — scan vs per-step loop bitwise, agreement with
the single-host engine under an accept-all filter, state conversion, and
buffer donation — on a (1, 1) mesh where shard_map is exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import (DistributedConfig,
                                    init_distributed_freshness,
                                    to_distributed_state)
from repro.core.freshness import FreshnessConfig
from repro.core.population import PopulationConfig, init_population
from repro.scenarios import (run_population, run_population_distributed,
                             run_population_distributed_loop,
                             run_sweep_distributed, stack_colocations,
                             stack_trees)

from conftest import assert_trees_bitwise, linear_population_setup

F, M, T = 4, 6, 15


def _mesh():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))


def _linear_setup(mode="fixed", seed=0, **fresh_kw):
    return linear_population_setup(mode, seed, n_fixed=F, n_mules=M,
                                   n_steps=T, **fresh_kw)


def _assert_trees_bitwise(a, b):
    assert_trees_bitwise(a, b, "distributed scan and reference diverged")


@pytest.mark.parametrize("mode", ["fixed", "mobile"])
@pytest.mark.parametrize("stat", ["median", "meanstd"])
def test_distributed_scan_matches_per_step_loop(mode, stat):
    """One shard_map'd scan == the per-step shard_map driver, bitwise."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(mode, stat=stat)
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    mesh, key = _mesh(), jax.random.PRNGKey(3)
    final, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                            dcfg, mesh, key)
    ref, ref_last = run_population_distributed_loop(
        dstate, co, batch_fn, train_fn, dcfg, mesh, key)
    _assert_trees_bitwise(final, ref)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]),
                                  np.asarray(ref_last))


@pytest.mark.parametrize("mode", ["fixed", "mobile"])
def test_distributed_matches_single_host_accept_all(mode):
    """With the filter accepting everything the two engines agree — the
    distributed key discipline (global split + shard slice) makes even the
    mobile-mode per-mule training draws identical."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(
        mode, init_threshold=1e9, warmup=10**6)
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(5)
    host, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    dist, _ = run_population_distributed(to_distributed_state(pop, dcfg),
                                         co, batch_fn, train_fn, dcfg,
                                         _mesh(), key)
    for k in ("fixed_models", "mule_models", "mule_ts"):
        for a, b in zip(jax.tree.leaves(host[k]), jax.tree.leaves(dist[k])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_distributed_eval_inside_scan():
    """Fixed-mode eval hook runs in-scan on the replicated state."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("fixed")
    dcfg = DistributedConfig(pop=pcfg)
    final, aux = run_population_distributed(
        to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), jax.random.PRNGKey(0), eval_every=5,
        eval_fn=lambda st, last: jnp.mean(st["fixed_models"]["w"]))
    np.testing.assert_array_equal(aux["eval_steps"], [4, 9, 14])
    assert np.asarray(aux["evals"]).shape == (3,)
    np.testing.assert_allclose(float(np.asarray(aux["evals"])[-1]),
                               float(jnp.mean(final["fixed_models"]["w"])),
                               rtol=1e-6)


def test_distributed_sweep_matches_sequential():
    """Lane i of a distributed sweep == the i-th sequential distributed
    run; the seed vmap stacks outside the shard_map mule axis."""
    seeds = [0, 1]
    setups = [_linear_setup("fixed", seed=s) for s in seeds]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    dcfg = DistributedConfig(pop=pcfg)
    mesh = _mesh()
    keys = [jax.random.PRNGKey(100 + s) for s in seeds]
    finals = [run_population_distributed(
        to_distributed_state(st, dcfg), co, batch_fn, train_fn, dcfg, mesh,
        k)[0] for (st, co, _, _, _), k in zip(setups, keys)]
    states = stack_trees([to_distributed_state(s[0], dcfg) for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    vf, aux = run_sweep_distributed(states, cos, batch_fn, train_fn, dcfg,
                                    mesh, stack_trees(keys))
    for i in range(len(seeds)):
        _assert_trees_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i])
    assert aux["last_fid"].shape == (len(seeds), M)


def test_to_distributed_state_carries_history():
    """Threshold and ring receipts survive the state conversion."""
    pcfg = PopulationConfig(n_fixed=2, n_mules=4)
    pop = init_population(jax.random.PRNGKey(0),
                          lambda k: {"w": jnp.zeros((3,))}, pcfg)
    pop["fresh"]["threshold"] = jnp.asarray([5.0, 7.0])
    pop["fresh"]["ages"] = pop["fresh"]["ages"].at[0, :3].set(
        jnp.asarray([1.0, 2.0, 3.0]))
    pop["fresh"]["count"] = jnp.asarray([3, 0], jnp.int32)
    dstate = to_distributed_state(pop, DistributedConfig(pop=pcfg))
    np.testing.assert_array_equal(np.asarray(dstate["fresh"]["threshold"]),
                                  [5.0, 7.0])
    np.testing.assert_array_equal(np.asarray(dstate["fresh"]["count"]),
                                  [3, 0])
    assert float(jnp.sum(dstate["fresh"]["hist"][0])) == 3.0
    assert float(jnp.sum(dstate["fresh"]["hist"][1])) == 0.0


def test_distributed_rejects_unsupported_methods_and_shapes():
    import types
    from repro.core.distributed import make_distributed_method_step
    from repro.core.method_program import get_program
    from repro.scenarios.engine import _check_mule_sharding
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("fixed")
    dcfg = DistributedConfig(pop=pcfg)
    with pytest.raises(ValueError, match="mlmule"):
        run_population_distributed(to_distributed_state(pop, dcfg), co,
                                   batch_fn, train_fn, dcfg, _mesh(),
                                   jax.random.PRNGKey(0), method="bogus")
    with pytest.raises(ValueError, match="mlmule"):
        get_program("bogus")
    # peer methods need the mesh to size the ring exchange
    with pytest.raises(ValueError, match="ring"):
        make_distributed_method_step("gossip", train_fn, dcfg)
    with pytest.raises(ValueError, match="stat"):
        init_distributed_freshness(2, FreshnessConfig(stat="bogus"))
    fake_mesh = types.SimpleNamespace(shape={"pod": 1, "data": 4})
    with pytest.raises(ValueError, match="divide"):
        _check_mule_sharding(6, fake_mesh, dcfg)   # 6 mules on 4 shards
    _check_mule_sharding(8, fake_mesh, dcfg)       # 8 on 4 is fine


def test_donated_replay_matches_undonated():
    """donate=True replays in place without changing results.

    Every donated call gets a freshly built (identically seeded) state —
    donation invalidates the input buffers, which is the whole point.
    """
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("fixed")
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(9)
    ref, _ = run_population_distributed(to_distributed_state(pop, dcfg), co,
                                        batch_fn, train_fn, dcfg, _mesh(),
                                        key)
    ref2, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    pop_d = _linear_setup("fixed")[0]              # same seed, fresh buffers
    donated, _ = run_population_distributed(
        to_distributed_state(pop_d, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), key, donate=True)
    _assert_trees_bitwise(ref, donated)
    pop_d2 = _linear_setup("fixed")[0]
    don2, _ = run_population(pop_d2, co, batch_fn, train_fn, pcfg, key,
                             donate=True)
    _assert_trees_bitwise(ref2, don2)


# ---------------------------------------------------------------------------
# population churn on the distributed engine
# ---------------------------------------------------------------------------


def _churned(co, seed=0):
    from repro.mobility import flash_churn_mask
    co = dict(co)
    co["active"] = flash_churn_mask(40 + seed, T, M, n_flashes=2,
                                    flash_len=5, base_frac=0.3)
    assert co["active"].any() and not co["active"].all()
    return co


@pytest.mark.parametrize("mode", ["fixed", "mobile"])
@pytest.mark.parametrize("stat", ["median", "meanstd"])
def test_churn_distributed_scan_matches_loop(mode, stat):
    """The mask folds into the fused psum payload: masked shard_map scan ==
    masked per-step shard_map driver, bitwise."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(mode, stat=stat)
    co = _churned(co)
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    mesh, key = _mesh(), jax.random.PRNGKey(13)
    final, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                            dcfg, mesh, key)
    ref, ref_last = run_population_distributed_loop(
        dstate, co, batch_fn, train_fn, dcfg, mesh, key)
    _assert_trees_bitwise(final, ref)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]),
                                  np.asarray(ref_last))


@pytest.mark.parametrize("mode", ["fixed", "mobile"])
@pytest.mark.parametrize("method", ["mlmule", "local"])
def test_churn_distributed_matches_single_host_bitwise(mode, method):
    """distributed == single-host under churn (1-device mesh is exact, so
    bitwise — inactive mules vanish identically from both reductions)."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(
        mode, init_threshold=1e9, warmup=10**6)
    co = _churned(co, seed=mode == "mobile")
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(17)
    host, haux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                method=method)
    dist, daux = run_population_distributed(
        to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), key, method=method)
    for k in ("fixed_models", "mule_models", "mule_ts"):
        _assert_trees_bitwise(host[k], dist[k])
    np.testing.assert_array_equal(np.asarray(haux["last_fid"]),
                                  np.asarray(daux["last_fid"]))


def test_churn_all_ones_mask_matches_dense_distributed():
    """All-ones mask == dense distributed replay, bitwise."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("fixed")
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(19)
    dense, _ = run_population_distributed(
        to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), key)
    co_ones = dict(co, active=np.ones((T, M), bool))
    masked, _ = run_population_distributed(
        to_distributed_state(pop, dcfg), co_ones, batch_fn, train_fn, dcfg,
        _mesh(), key)
    _assert_trees_bitwise(masked, dense)


# ---------------------------------------------------------------------------
# sharded peer-encounter baselines (ring ppermute exchange)
# ---------------------------------------------------------------------------

PEER_METHODS = ("gossip", "oppcl", "mlmule+gossip")


@pytest.mark.parametrize("method", PEER_METHODS)
def test_peer_distributed_scan_matches_loop(method):
    """Ring-sharded peer baselines: shard_map scan == per-step shard_map
    driver, bitwise (the ring + cadence cond fold into the scan body)."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    mesh, key = _mesh(), jax.random.PRNGKey(41)
    final, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                            dcfg, mesh, key, method=method)
    ref, ref_last = run_population_distributed_loop(
        dstate, co, batch_fn, train_fn, dcfg, mesh, key, method=method)
    _assert_trees_bitwise(final, ref)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]),
                                  np.asarray(ref_last))


@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("method", ("gossip", "oppcl"))
def test_peer_distributed_matches_single_host_bitwise(method, masked):
    """gossip/oppcl distributed == single-host, bitwise, dense and
    churn-masked (a 1-shard ring is exactly the single-host encounter
    computation; training keys come from the same global split)."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    if masked:
        co = _churned(co, seed=7)
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(43)
    host, haux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                method=method)
    dist, daux = run_population_distributed(
        to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), key, method=method)
    _assert_trees_bitwise(host["mule_models"], dist["mule_models"])
    np.testing.assert_array_equal(np.asarray(haux["last_fid"]),
                                  np.asarray(daux["last_fid"]))


def test_hybrid_distributed_matches_single_host_bitwise():
    """mlmule+gossip: the fused-psum space exchange AND the ring gossip
    exchange both match single host on the 1-device mesh (accept-all
    freshness filter bridges the freshness-state layouts)."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(
        "mobile", init_threshold=1e9, warmup=10**6)
    co = _churned(co, seed=3)
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(47)
    host, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                             method="mlmule+gossip")
    dist, _ = run_population_distributed(
        to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
        _mesh(), key, method="mlmule+gossip")
    for k in ("fixed_models", "mule_models", "mule_ts"):
        _assert_trees_bitwise(host[k], dist[k])


def test_peer_distributed_sweep_matches_sequential():
    """The seed vmap composes with the ring ppermute: lane i of a
    distributed gossip sweep == the i-th sequential distributed run."""
    seeds = [0, 1]
    setups = [_linear_setup("mobile", seed=s) for s in seeds]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    dcfg = DistributedConfig(pop=pcfg)
    mesh = _mesh()
    keys = [jax.random.PRNGKey(700 + s) for s in seeds]
    finals = [run_population_distributed(
        to_distributed_state(st, dcfg), co, batch_fn, train_fn, dcfg, mesh,
        k, method="gossip")[0]
        for (st, co, _, _, _), k in zip(setups, keys)]
    states = stack_trees([to_distributed_state(s[0], dcfg) for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    vf, _ = run_sweep_distributed(states, cos, batch_fn, train_fn, dcfg,
                                  mesh, stack_trees(keys), methods="gossip")
    for i in range(len(seeds)):
        _assert_trees_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i])


def test_migrate_mules_single_pod_identity():
    """On a 1-pod mesh the migration ring is a self-loop: flagged or not,
    every leaf round-trips bitwise (multi-pod round trip: slow tier)."""
    from repro.core.distributed import migrate_mules
    mesh = _mesh()
    models = {"w": jnp.arange(12, dtype=jnp.float32).reshape(6, 2)}
    mask = jnp.array([True, False, True, True, False, False])
    out = migrate_mules(models, mask, mesh)
    _assert_trees_bitwise(out, models)


def test_churn_distributed_sweep_matches_sequential():
    """Per-seed churn masks ride the distributed sweep's seed vmap."""
    seeds = [0, 1]
    setups = [_linear_setup("fixed", seed=s) for s in seeds]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    cos = [_churned(st[1], seed=s) for s, st in zip(seeds, setups)]
    dcfg = DistributedConfig(pop=pcfg)
    mesh = _mesh()
    keys = [jax.random.PRNGKey(500 + s) for s in seeds]
    finals = [run_population_distributed(
        to_distributed_state(st, dcfg), co, batch_fn, train_fn, dcfg, mesh,
        k)[0] for (st, _, _, _, _), co, k in zip(setups, cos, keys)]
    states = stack_trees([to_distributed_state(s[0], dcfg) for s in setups])
    vf, _ = run_sweep_distributed(states, stack_colocations(cos), batch_fn,
                                  train_fn, dcfg, mesh, stack_trees(keys))
    for i in range(len(seeds)):
        _assert_trees_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i])
