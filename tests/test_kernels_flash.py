"""Flash attention kernel: shape/dtype sweeps vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_reference, mha_reference

CASES = [
    # b, s, h, kv, d, window, causal
    (2, 128, 4, 2, 32, None, True),
    (1, 200, 4, 4, 16, None, True),       # ragged seq vs blocks
    (2, 256, 8, 2, 32, 64, True),         # sliding window
    (1, 128, 4, 2, 32, None, False),      # bidirectional (encoder)
    (2, 96, 4, 1, 64, 48, True),          # MQA + window
    (1, 64, 2, 2, 8, 16, True),           # tiny window
]


def _mk(b, s, h, kv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_ref_matches_oracle(case, dtype):
    b, s, h, kv, d, win, causal = case
    q, k, v = _mk(b, s, h, kv, d, dtype)
    ref = mha_reference(q, k, v, causal=causal, window=win)
    out = flash_reference(q, k, v, causal=causal, window=win,
                          block_q=64, block_k=64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", CASES)
def test_pallas_interpret_matches_oracle(case):
    b, s, h, kv, d, win, causal = case
    q, k, v = _mk(b, s, h, kv, d, jnp.float32)
    ref = mha_reference(q, k, v, causal=causal, window=win)
    out = flash_attention(q, k, v, causal=causal, window=win, block_q=64,
                          block_k=64, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("case", CASES[:4])
def test_custom_vjp_matches_autodiff(case):
    b, s, h, kv, d, win, causal = case
    q, k, v = _mk(b, s, h, kv, d, jnp.float32)

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(mha_reference(q, k, v, causal=causal, window=win)))

    def loss_fl(q, k, v):
        return jnp.sum(jnp.sin(flash_reference(q, k, v, causal=causal,
                                               window=win, block_q=64, block_k=64)))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5)


def test_decode_alignment():
    """Right-aligned queries (q shorter than k) match the oracle."""
    b, sq, sk, h, kv, d = 2, 4, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    ref = mha_reference(q, k, v, causal=True)
    out = flash_reference(q, k, v, causal=True, block_q=4, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
