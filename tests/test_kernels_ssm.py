"""SSD (Mamba2) scan kernel: chunked/pallas vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan.kernel import ssd_scan_pallas
from repro.kernels.ssm_scan.ref import ssd_chunked_reference, ssd_reference

CASES = [
    # b, s, h, p, n, chunk
    (2, 64, 3, 8, 16, 16),
    (1, 100, 2, 16, 8, 32),     # ragged
    (2, 128, 4, 32, 16, 64),
    (1, 33, 1, 4, 4, 8),
]


def _mk(b, s, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_sequential(case):
    b, s, h, p, n, chunk = case
    x, dt, A, B, C = _mk(b, s, h, p, n)
    y1, s1 = ssd_reference(x, dt, A, B, C)
    y2, s2 = ssd_chunked_reference(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-4)


@pytest.mark.parametrize("case", CASES)
def test_pallas_interpret_matches_sequential(case):
    b, s, h, p, n, chunk = case
    x, dt, A, B, C = _mk(b, s, h, p, n)
    y1, _ = ssd_reference(x, dt, A, B, C)
    y2, _ = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


def test_init_state_carry():
    b, s, h, p, n = 2, 48, 2, 8, 8
    x, dt, A, B, C = _mk(b, s, h, p, n, seed=7)
    init = jax.random.normal(jax.random.PRNGKey(9), (b, h, p, n))
    y1, s1 = ssd_reference(x, dt, A, B, C, init_state=init)
    y2, s2 = ssd_chunked_reference(x, dt, A, B, C, chunk=16, init_state=init)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_split_scan_equals_full():
    """Running two halves with state handoff == one full scan (the
    prefill->decode handoff invariant)."""
    b, s, h, p, n = 1, 64, 2, 8, 8
    x, dt, A, B, C = _mk(b, s, h, p, n, seed=11)
    y_full, s_full = ssd_reference(x, dt, A, B, C)
    half = s // 2
    y1, st = ssd_reference(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half])
    y2, s2 = ssd_reference(x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:],
                           init_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)
