"""MethodProgram contract: one table, two lowerings, extensible by data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.method_program import (METHOD_PROGRAMS, MethodProgram,
                                       compile_distributed_step,
                                       compile_step, get_program)
from repro.core.population import METHODS_MOBILE

from conftest import assert_trees_bitwise, linear_population_setup


def test_table_covers_every_method():
    """Every METHODS_MOBILE name resolves to a program; both engine entry
    points are thin wrappers over the same table."""
    assert set(METHOD_PROGRAMS) == set(METHODS_MOBILE)
    for name in METHODS_MOBILE:
        assert get_program(name).name == name
    with pytest.raises(ValueError, match="mlmule"):
        get_program("fedavg")


def test_programs_declare_expected_pieces():
    """The declarations encode the paper's method semantics."""
    assert METHOD_PROGRAMS["mlmule"].space_exchange
    assert METHOD_PROGRAMS["mlmule"].peer_exchange is None
    assert METHOD_PROGRAMS["gossip"].peer_exchange == "gossip"
    assert METHOD_PROGRAMS["gossip"].peer_every == 3   # paper Sec 4.3.1
    assert METHOD_PROGRAMS["oppcl"].peer_exchange == "oppcl"
    assert METHOD_PROGRAMS["local"].local_train
    hybrid = METHOD_PROGRAMS["mlmule+gossip"]
    assert hybrid.space_exchange and hybrid.peer_exchange == "gossip"
    assert hybrid.peer_key_fold == 1


def test_method_six_registers_and_runs_on_both_engines():
    """The documented extension path: a sixth method is one table entry —
    no engine code. A faster-cadence gossip must fire on steps the stock
    program skips, and the single-host and 1-shard-distributed lowerings
    of the new program must agree bitwise."""
    from repro.core.distributed import DistributedConfig, to_distributed_state
    from repro.scenarios import run_population, run_population_distributed

    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        "mobile", n_fixed=4, n_mules=6, n_steps=7)
    key = jax.random.PRNGKey(11)
    METHOD_PROGRAMS["gossip1"] = MethodProgram("gossip1",
                                               peer_exchange="gossip",
                                               peer_every=1)
    try:
        fast, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                 method="gossip1")
        stock, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                  method="gossip")
        assert not np.array_equal(np.asarray(fast["mule_models"]["w"]),
                                  np.asarray(stock["mule_models"]["w"]))
        dcfg = DistributedConfig(pop=pcfg)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))
        dist, _ = run_population_distributed(
            to_distributed_state(pop, dcfg), co, batch_fn, train_fn, dcfg,
            mesh, key, method="gossip1")
        assert_trees_bitwise(fast["mule_models"], dist["mule_models"],
                             "method-6 lowerings diverged")
    finally:
        del METHOD_PROGRAMS["gossip1"]


def test_compiled_steps_share_signature():
    """Both lowerings return the uniform (state, info, batches, key) step
    for every program (peer programs need a ring size distributed)."""
    from repro.core.distributed import DistributedConfig
    _, _, _, train_fn, pcfg = linear_population_setup("mobile")
    area = jnp.zeros((6,), jnp.int32)
    dcfg = DistributedConfig(pop=pcfg)
    for name in METHODS_MOBILE:
        assert callable(compile_step(get_program(name), train_fn, pcfg,
                                     area))
        assert callable(compile_distributed_step(get_program(name),
                                                 train_fn, dcfg,
                                                 ring_size=1))
