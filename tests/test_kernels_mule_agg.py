"""mule_agg kernel: interpret-mode vs oracle + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.core.aggregation import masked_group_mean, weighted_average
from repro.kernels.mule_agg.kernel import mule_agg_pallas
from repro.kernels.mule_agg.ref import mule_agg_reference


@pytest.mark.parametrize("f,m,d,block_d", [
    (8, 20, 256, 128), (8, 20, 1000, 256), (2, 3, 64, 64),
    (16, 64, 4096, 2048), (1, 1, 130, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(f, m, d, block_d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    assign = jax.random.uniform(k1, (f, m), jnp.float32)
    w = jax.random.normal(k2, (m, d), dtype)
    ref = mule_agg_reference(assign, w)
    out = mule_agg_pallas(assign, w, block_d=block_d, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@settings(max_examples=25, deadline=None)
@given(f=st.integers(1, 6), m=st.integers(1, 12), d=st.integers(1, 64),
       seed=st.integers(0, 10_000))
def test_group_mean_convexity(f, m, d, seed):
    """Group means lie inside the convex hull of member values (per coord)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (m, d))
    assign = (jax.random.uniform(k2, (f, m)) > 0.5).astype(jnp.float32)
    models = {"w": w}
    out, mass = masked_group_mean(models, assign)
    for fi in range(f):
        members = np.where(np.asarray(assign)[fi] > 0)[0]
        if len(members) == 0:
            continue
        sub = np.asarray(w)[members]
        got = np.asarray(out["w"])[fi]
        assert (got <= sub.max(0) + 1e-5).all()
        assert (got >= sub.min(0) - 1e-5).all()


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 8), d=st.integers(1, 32), seed=st.integers(0, 10_000))
def test_weighted_average_affine_equivariance(m, d, seed):
    """avg(a*W + b) == a*avg(W) + b — aggregation must be affine."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (m, d))
    weights = jax.random.uniform(k2, (m,)) + 0.1
    base = weighted_average({"w": w}, weights)["w"]
    shifted = weighted_average({"w": 2.5 * w - 1.0}, weights)["w"]
    np.testing.assert_allclose(np.asarray(shifted), np.asarray(2.5 * base - 1.0),
                               atol=1e-5)


def test_group_mean_pallas_backend():
    models = {"a": jax.random.normal(jax.random.PRNGKey(0), (10, 33)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (10, 4, 7))}
    assign = (jax.random.uniform(jax.random.PRNGKey(2), (4, 10)) > 0.4).astype(jnp.float32)
    ref, mass_r = masked_group_mean(models, assign, backend="ref")
    out, mass_p = masked_group_mean(models, assign, backend="interpret")
    for k in models:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mass_r), np.asarray(mass_p))
