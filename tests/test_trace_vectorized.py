"""Vectorized trace expansion: exact parity with the per-step-loop reference
plus the paper's co-location invariants, across all trace generators."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.mobility import (commuter_trace, event_crowd_trace,
                            shift_worker_trace, synth_foursquare_trace,
                            trace_to_colocation, trace_to_colocation_loop)
from repro.scenarios import SCENARIOS, get_scenario

GENERATORS = [synth_foursquare_trace, commuter_trace, shift_worker_trace,
              event_crowd_trace]


@pytest.mark.parametrize("gen", GENERATORS, ids=lambda g: g.__name__)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_matches_loop(gen, seed):
    m, t = 14, 400
    visits = gen(seed, n_users=m, n_places=8, n_steps=t)
    fid_v, ex_v = trace_to_colocation(visits, m, t)
    fid_l, ex_l = trace_to_colocation_loop(visits, m, t)
    np.testing.assert_array_equal(fid_v, fid_l)
    np.testing.assert_array_equal(ex_v, ex_l)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_visits=st.integers(0, 60),
       exchange_steps=st.integers(1, 5))
def test_vectorized_matches_loop_random_visits(seed, n_visits, exchange_steps):
    """Arbitrary (possibly overlapping, out-of-range) visit logs."""
    m, t = 6, 80
    rng = np.random.default_rng(seed)
    u = rng.integers(0, m, n_visits)
    place = rng.integers(0, 4, n_visits)
    t_in = rng.integers(0, t, n_visits)
    t_out = t_in + rng.integers(1, 30, n_visits)     # may exceed t
    visits = np.stack([u, place, t_in, t_out], axis=1).astype(np.int64)
    visits = visits[np.argsort(visits[:, 2], kind="stable")]
    fid_v, ex_v = trace_to_colocation(visits, m, t, exchange_steps)
    fid_l, ex_l = trace_to_colocation_loop(visits, m, t, exchange_steps)
    np.testing.assert_array_equal(fid_v, fid_l)
    np.testing.assert_array_equal(ex_v, ex_l)


@pytest.mark.parametrize("gen", GENERATORS, ids=lambda g: g.__name__)
def test_colocation_invariants(gen):
    m, t, k = 12, 300, 3
    visits = gen(5, n_users=m, n_places=8, n_steps=t)
    fid, exch = trace_to_colocation(visits, m, t, exchange_steps=k)
    assert fid.shape == (t, m) and exch.shape == (t, m)
    # exchange => co-located
    assert (fid[exch] >= 0).all()
    # dwell cadence: an exchange fires exactly every k-th consecutive step
    # of one visit (dwell counter resets on place change or absence)
    dwell = np.zeros(m, np.int64)
    prev = -np.ones(m, np.int32)
    for step in range(t):
        same = (fid[step] == prev) & (fid[step] >= 0)
        dwell = np.where(same, dwell + 1, np.where(fid[step] >= 0, 1, 0))
        np.testing.assert_array_equal(
            exch[step], (dwell > 0) & (dwell % k == 0))
        prev = fid[step]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_registry_colocation_contract(name):
    m, t = 10, 120
    co = get_scenario(name).colocation(0, m, t)
    assert co["fixed_id"].shape == (t, m)
    assert co["exchange"].shape == (t, m) and co["exchange"].dtype == bool
    assert co["pos"].shape == (t, m, 2)
    for k in ("init_space", "init_area"):
        assert co[k].shape == (m,), k
    # area is per-mule, or a [T, M] trace for the migratory scenarios
    assert co["area"].shape in ((m,), (t, m)), co["area"].shape
    assert (co["fixed_id"][co["exchange"]] >= 0).all()
    assert (co["init_space"] >= 0).all() and (co["init_space"] < 4).all()
    assert (co["exchange"] & (co["fixed_id"] >= 0)).any(), \
        f"scenario {name} never completes an exchange"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_visits=st.integers(0, 60))
def test_vectorized_matches_loop_per_place_cadence(seed, n_visits):
    """Heterogeneous exchange tempos: a per-place exchange_steps array
    expands identically in the vectorized and loop implementations."""
    m, t = 6, 80
    rng = np.random.default_rng(seed)
    cadence = rng.integers(1, 9, 4)
    u = rng.integers(0, m, n_visits)
    place = rng.integers(0, 4, n_visits)
    t_in = rng.integers(0, t, n_visits)
    t_out = t_in + rng.integers(1, 25, n_visits)
    visits = np.stack([u, place, t_in, np.minimum(t_out, t)], axis=1)
    visits = visits[np.argsort(visits[:, 2], kind="stable")]
    fid_v, ex_v = trace_to_colocation(visits, m, t, exchange_steps=cadence)
    fid_l, ex_l = trace_to_colocation_loop(visits, m, t,
                                           exchange_steps=cadence)
    np.testing.assert_array_equal(fid_v, fid_l)
    np.testing.assert_array_equal(ex_v, ex_l)
    # each exchange fires on its own space's cadence
    tt, mm = np.nonzero(ex_v)
    assert (fid_v[tt, mm] >= 0).all()
