"""Autotuner tests: cache lookups (env repoint / disable / fallback),
engine roofline rows, artifact production, and the differential-numerics
invariant — every block size the tuner may select yields BITWISE-identical
kernel output, so autotuning can change performance but never results."""
import json

import jax
import numpy as np
import pytest

from repro.launch import autotune
from repro.launch.autotune import (ENCOUNTER_BLOCK_D_CANDIDATES,
                                   ENCOUNTER_BLOCK_M_CANDIDATES,
                                   MULE_AGG_BLOCK_D_CANDIDATES,
                                   VMEM_BUDGET_BYTES, analyze_engine_step,
                                   encounter_tile_bytes, mule_agg_tile_bytes,
                                   tuned_block_d, tuned_encounter_blocks,
                                   tuning_cache_clear)


@pytest.fixture(autouse=True)
def _fresh_cache():
    # the lookup memoizes the default-resolution cache; tests repoint
    # REPRO_TUNE_CACHE, so drop the memo on both sides of every test
    tuning_cache_clear()
    yield
    tuning_cache_clear()


def _write_cache(path, tuned):
    path.write_text(json.dumps(
        {"bench": "autotune.run_roofline", "config": {}, "roofline": [],
         "tuned": tuned, "tuned_speedup_vs_default": 1.0}))


# ---------------------------------------------------------------------------
# tuning-cache lookup
# ---------------------------------------------------------------------------


def test_cache_lookup_nearest_shape(tmp_path, monkeypatch):
    cache = tmp_path / "cache.json"
    _write_cache(cache, {
        "mule_agg": [{"f": 8, "m": 64, "d": 4096, "block_d": 512},
                     {"f": 8, "m": 64, "d": 65536, "block_d": 2048}],
        "encounter_mix": [
            {"m": 512, "d": 480, "block_m": 128, "block_d": 256},
            {"m": 4096, "d": 480, "block_m": 512, "block_d": 1024}]})
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    assert tuned_block_d(4000) == 512          # nearest |log d ratio|
    assert tuned_block_d(1 << 17) == 2048
    assert tuned_encounter_blocks(600, 480) == (128, 256)
    assert tuned_encounter_blocks(3000, 480) == (512, 1024)


def test_cache_env_empty_disables(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", "")
    tuning_cache_clear()
    assert tuned_block_d(1 << 18) == autotune.MULE_AGG_DEFAULT_BLOCK_D
    assert tuned_encounter_blocks(1024, 480) == \
        autotune.ENCOUNTER_DEFAULT_BLOCKS


def test_cache_missing_or_malformed_falls_back(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "absent.json"))
    tuning_cache_clear()
    assert tuned_block_d(4096) == autotune.MULE_AGG_DEFAULT_BLOCK_D
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(bad))
    tuning_cache_clear()
    assert tuned_encounter_blocks(64, 64) == \
        autotune.ENCOUNTER_DEFAULT_BLOCKS
    # schema-valid JSON without a tuned section reads as "no cache" too
    bad.write_text(json.dumps({"tuned": "oops"}))
    tuning_cache_clear()
    assert tuned_block_d(4096) == autotune.MULE_AGG_DEFAULT_BLOCK_D


def test_committed_cache_drives_the_kernels():
    """The repo's own BENCH_roofline.json is what pick_block_d and the
    encounter wrapper consult by default."""
    from repro.kernels.mule_agg.ops import pick_block_d
    cache = autotune.load_tuning_cache()
    assert cache is not None, "committed BENCH_roofline.json must parse"
    entry = cache["tuned"]["mule_agg"][-1]
    assert pick_block_d(entry["d"]) == entry["block_d"]
    em = cache["tuned"]["encounter_mix"][0]
    assert tuned_encounter_blocks(em["m"], em["d"]) == \
        (em["block_m"], em["block_d"])


def test_explicit_block_beats_cache(tmp_path, monkeypatch):
    from repro.kernels.mule_agg.ops import mule_agg, pick_block_d
    cache = tmp_path / "cache.json"
    _write_cache(cache, {"mule_agg": [{"f": 2, "m": 8, "d": 256,
                                       "block_d": 256}],
                         "encounter_mix": []})
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    assert pick_block_d(256) == 256
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.uniform(k1, (2, 8))
    w = jax.random.normal(k2, (8, 256))
    ref = np.asarray(mule_agg(a, w, backend="ref"))
    out = np.asarray(mule_agg(a, w, block_d=128, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# differential numerics: tuning may move blocks, results must not move
# ---------------------------------------------------------------------------


def test_mule_agg_bitwise_identical_across_candidates():
    f, m, d = 4, 24, 1000                      # d indivisible by every block
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    assign = jax.random.uniform(k1, (f, m))
    w = jax.random.normal(k2, (m, d))
    from repro.kernels.mule_agg.kernel import mule_agg_pallas
    from repro.kernels.mule_agg.ref import mule_agg_reference
    blocks = sorted({min(b, max(128, d)) for b in MULE_AGG_BLOCK_D_CANDIDATES
                     if mule_agg_tile_bytes(f, m, min(b, max(128, d)))
                     <= VMEM_BUDGET_BYTES})
    assert len(blocks) >= 3                    # a real sweep, not one cell
    outs = [np.asarray(mule_agg_pallas(assign, w, block_d=b, interpret=True))
            for b in blocks]
    for b, o in zip(blocks[1:], outs[1:]):
        assert np.array_equal(outs[0], o), f"block_d={b} changed the output"
    np.testing.assert_allclose(
        outs[0], np.asarray(mule_agg_reference(assign, w)),
        rtol=2e-5, atol=2e-5)


def test_encounter_mix_bitwise_identical_across_candidates():
    # M divides every block_m candidate so the padded contraction length is
    # the same for all tiles (block_m changes it otherwise, and a different
    # reduction length is not bitwise-stable on CPU — see the padded test
    # below); D stays indivisible to exercise column padding
    m, d = 512, 520
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    pos = jax.random.uniform(ks[0], (m, 2))
    area = jax.random.randint(ks[1], (m,), 0, 2)
    active = jax.random.uniform(ks[2], (m,)) < 0.9
    w = jax.random.normal(ks[3], (m, d))
    from repro.kernels.encounter_mix.kernel import encounter_mix_pallas
    from repro.kernels.encounter_mix.ref import encounter_mix_reference
    pairs = sorted({(min(bm, max(8, m)), min(bd, max(128, d)))
                    for bm in ENCOUNTER_BLOCK_M_CANDIDATES
                    for bd in ENCOUNTER_BLOCK_D_CANDIDATES
                    if encounter_tile_bytes(m, min(bm, max(8, m)),
                                            min(bd, max(128, d)))
                    <= VMEM_BUDGET_BYTES})
    assert len(pairs) >= 4
    outs = []
    for bm, bd in pairs:
        mix, mass = encounter_mix_pallas(pos, area, active, w, radius=0.12,
                                         block_m=bm, block_d=bd,
                                         interpret=True)
        outs.append((np.asarray(mix), np.asarray(mass)))
    for (bm, bd), (mix, mass) in zip(pairs[1:], outs[1:]):
        assert np.array_equal(outs[0][0], mix), \
            f"blocks ({bm},{bd}) changed the mix"
        assert np.array_equal(outs[0][1], mass), \
            f"blocks ({bm},{bd}) changed the mass"
    ref_mix, ref_mass = encounter_mix_reference(pos, area, active, w,
                                                radius=0.12)
    np.testing.assert_allclose(outs[0][1], np.asarray(ref_mass),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs[0][0], np.asarray(ref_mix),
                               rtol=2e-5, atol=2e-5)


def test_encounter_mix_padded_rows_still_exact_vs_reference():
    # when block_m does NOT divide M the zero-padded contraction length
    # differs per candidate — bitwise identity is then out of reach on CPU
    # (reduction order), but every candidate must still match the oracle
    m, d = 300, 520
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    pos = jax.random.uniform(ks[0], (m, 2))
    area = jax.random.randint(ks[1], (m,), 0, 2)
    active = jax.random.uniform(ks[2], (m,)) < 0.9
    w = jax.random.normal(ks[3], (m, d))
    from repro.kernels.encounter_mix.kernel import encounter_mix_pallas
    from repro.kernels.encounter_mix.ref import encounter_mix_reference
    ref_mix, ref_mass = encounter_mix_reference(pos, area, active, w,
                                                radius=0.12)
    for bm in (128, 256, 300):
        mix, mass = encounter_mix_pallas(pos, area, active, w, radius=0.12,
                                         block_m=bm, block_d=256,
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(mass), np.asarray(ref_mass),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(mix), np.asarray(ref_mix),
                                   rtol=2e-5, atol=2e-5)


def test_tile_models_fit_the_budget():
    # every default-shape candidate the tuner sweeps must be VMEM-feasible
    for b in MULE_AGG_BLOCK_D_CANDIDATES:
        assert mule_agg_tile_bytes(8, 64, b) <= VMEM_BUDGET_BYTES
    assert encounter_tile_bytes(2048, 256, 1024) <= VMEM_BUDGET_BYTES
    # and the model is monotone in each tile dim
    assert mule_agg_tile_bytes(8, 64, 512) < mule_agg_tile_bytes(8, 64, 1024)
    assert encounter_tile_bytes(512, 128, 256) < \
        encounter_tile_bytes(512, 256, 256)


# ---------------------------------------------------------------------------
# engine roofline + artifact production
# ---------------------------------------------------------------------------


def test_analyze_engine_step_terms():
    row = analyze_engine_step("mlmule", n_mules=8, steps=6)
    assert row["method"] == "mlmule"
    assert row["mesh"] == "1" and row["chips"] == 1
    assert row["flops_per_device"] > 0
    assert row["bytes_per_device"] > 0
    assert row["coll_bytes_per_device"] == 0   # single host: no collectives
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["t_memory_us_per_step"] == pytest.approx(
        row["t_memory_s"] / 6 * 1e6)


def test_run_roofline_artifact_validates(tmp_path):
    """A freshly produced artifact satisfies the bench_gate schema and
    round-trips through the regression gate against itself."""
    from benchmarks import bench_gate
    out = tmp_path / "BENCH_roofline.json"
    payload = autotune.run_roofline(
        str(out), reps=1, steps=4, mule_counts=(8,), methods=("local",),
        mule_agg_shapes=((2, 8, 512),), encounter_shapes=((64, 96),))
    schema = bench_gate.validate("BENCH_roofline.json", payload)
    assert schema.headline == "tuned_speedup_vs_default"
    on_disk = json.loads(out.read_text())
    assert on_disk["tuned_speedup_vs_default"] == \
        payload["tuned_speedup_vs_default"]
    rows = on_disk["roofline"]
    assert [r["method"] for r in rows] == ["local"]
    assert rows[0]["n_mules"] == 8
    # the gate passes an artifact against itself, always
    assert bench_gate.gate_artifact("BENCH_roofline.json",
                                    on_disk, payload).ok


# ---------------------------------------------------------------------------
# roofline-driven mesh-shape suggestion
# ---------------------------------------------------------------------------


def _mesh_row(mesh, method, n_mules, coll, mem):
    return {"mesh": mesh, "method": method, "n_mules": n_mules,
            "t_collective_us_per_step": coll, "t_memory_us_per_step": mem}


def _write_mesh_cache(path, rows):
    path.write_text(json.dumps(
        {"bench": "autotune.run_roofline", "config": {}, "roofline": rows,
         "tuned": {"mule_agg": [], "encounter_mix": []},
         "tuned_speedup_vs_default": 1.0}))


def test_suggest_mesh_shape_minimizes_coll_plus_mem(tmp_path, monkeypatch):
    cache = tmp_path / "cache.json"
    _write_mesh_cache(cache, [
        _mesh_row("1x8", "gossip", 64, 10.0, 5.0),     # cost 15
        _mesh_row("2x4", "gossip", 64, 4.0, 5.0),      # cost 9  <- min
        _mesh_row("4x2", "gossip", 64, 9.0, 9.0),      # cost 18
        _mesh_row("1", "gossip", 64, 0.0, 0.0),        # host row: not a shape
    ])
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    assert autotune.suggest_mesh_shape("gossip", 64) == (2, 4)


def test_suggest_mesh_shape_method_filter_and_fallback(tmp_path, monkeypatch):
    cache = tmp_path / "cache.json"
    _write_mesh_cache(cache, [
        _mesh_row("1x8", "gossip", 64, 1.0, 1.0),
        _mesh_row("2x4", "oppcl", 64, 0.5, 0.5),
    ])
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    # rows for the method win even when another method's row is cheaper
    assert autotune.suggest_mesh_shape("gossip", 64) == (1, 8)
    assert autotune.suggest_mesh_shape("oppcl", 64) == (2, 4)
    # unknown method falls back to all mesh rows -> global min
    assert autotune.suggest_mesh_shape("mlmule", 64) == (2, 4)


def test_suggest_mesh_shape_nearest_population(tmp_path, monkeypatch):
    cache = tmp_path / "cache.json"
    _write_mesh_cache(cache, [
        _mesh_row("1x8", "gossip", 32, 1.0, 1.0),      # cheap at M=32
        _mesh_row("1x8", "gossip", 4096, 50.0, 50.0),  # dear at M=4096
        _mesh_row("2x4", "gossip", 32, 30.0, 30.0),
        _mesh_row("2x4", "gossip", 4096, 20.0, 20.0),
    ])
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    assert autotune.suggest_mesh_shape("gossip", 16) == (1, 8)
    assert autotune.suggest_mesh_shape("gossip", 8192) == (2, 4)


def test_suggest_mesh_shape_without_cache_or_rows(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", "")
    tuning_cache_clear()
    assert autotune.suggest_mesh_shape("gossip", 64) is None
    cache = tmp_path / "cache.json"
    _write_mesh_cache(cache, [
        _mesh_row("1", "gossip", 64, 1.0, 1.0),        # host rows only
        {"mesh": "2x4", "method": "gossip", "n_mules": 64},  # terms missing
        _mesh_row("axb", "gossip", 64, 1.0, 1.0),      # unparseable shape
    ])
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
    tuning_cache_clear()
    assert autotune.suggest_mesh_shape("gossip", 64) is None


def test_committed_roofline_suggests_a_real_shape():
    """The repo's committed artifact carries per-mesh rows; the suggestion
    must come back as a usable 8-chip shape for every peer method."""
    for method in ("gossip", "oppcl", "mlmule", "mlmule+gossip"):
        shape = autotune.suggest_mesh_shape(method, 64)
        assert shape is not None and shape[0] * shape[1] == 8, (method, shape)


def test_tune_handles_tiny_shapes():
    # candidates clamp exactly like the kernels; a shape smaller than every
    # candidate must still tune (regression: empty-candidate crash)
    r = autotune.tune_mule_agg(2, 8, 64, reps=1)
    assert r["block_d"] == 128                 # max(128, d=64)
    e = autotune.tune_encounter_mix(16, 32, reps=1)
    assert (e["block_m"], e["block_d"]) == (16, 128)
    assert e["speedup_vs_default"] >= 0
