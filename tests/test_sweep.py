"""Sweep engine parity: every METHODS_MOBILE method on the scan engine
matches the retired per-step loop bitwise, vmapped multi-seed sweeps match
sequential ``run_population`` calls bitwise, and the jit cache stops
retracing on repeat same-shape calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.population import METHODS_MOBILE
from repro.scenarios import (jit_cache_clear, jit_cache_stats,
                             run_population, run_population_loop, run_sweep,
                             stack_colocations, stack_trees)

from conftest import assert_trees_bitwise, linear_population_setup

F, M, T = 4, 6, 18


def _linear_setup(mode="mobile", seed=0):
    return linear_population_setup(mode, seed, n_fixed=F, n_mules=M,
                                   n_steps=T)


def _assert_trees_bitwise(a, b):
    assert_trees_bitwise(a, b, "scan and reference diverged")


@pytest.mark.parametrize("method", METHODS_MOBILE)
def test_method_scan_matches_loop(method):
    """Scan-folded baselines == the old per-step Python driver, bitwise."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    key = jax.random.PRNGKey(3)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                method=method)
    ref, ref_last = run_population_loop(pop, co, batch_fn, train_fn, pcfg,
                                        key, method=method)
    _assert_trees_bitwise(final, ref)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]),
                                  np.asarray(ref_last))


def test_local_method_fixed_mode_matches_loop():
    """Table-1's local baseline runs in fixed mode; same parity there."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("fixed")
    key = jax.random.PRNGKey(5)
    final, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                              method="local")
    ref, _ = run_population_loop(pop, co, batch_fn, train_fn, pcfg, key,
                                 method="local")
    _assert_trees_bitwise(final, ref)


def test_gossip_cadence_only_fires_every_third_step():
    """Between exchange steps (t % 3 != 2) gossip must carry models."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    co2 = {k: (v[:2] if np.ndim(v) > 1 and np.shape(v)[0] == T else v)
           for k, v in co.items()}                       # steps 0..1 only
    final, _ = run_population(pop, co2, batch_fn, train_fn, pcfg,
                              jax.random.PRNGKey(0), method="gossip")
    _assert_trees_bitwise(final["mule_models"], pop["mule_models"])


@pytest.mark.parametrize("method", ["mlmule", "gossip"])
def test_sweep_matches_sequential_bitwise(method):
    """Lane i of a vmapped k-seed sweep == the i-th sequential run."""
    seeds = [0, 1, 2]
    setups = [_linear_setup("mobile", seed=s) for s in seeds]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    keys = [jax.random.PRNGKey(100 + s) for s in seeds]

    finals = []
    for (pop, co, _, _, _), key in zip(setups, keys):
        f, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                              method=method)
        finals.append(f)

    states = stack_trees([s[0] for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    vf, aux = run_sweep(states, cos, batch_fn, train_fn, pcfg,
                        stack_trees(keys), methods=method)
    for i in range(len(seeds)):
        _assert_trees_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i])
    assert aux["last_fid"].shape == (len(seeds), M)


def test_sweep_shared_colocation_and_method_dict():
    """A single [T, M] schedule broadcasts across seeds; a sequence of
    methods returns a per-method dict of stacked results."""
    pop0, co, batch_fn, train_fn, pcfg = _linear_setup("mobile", seed=0)
    pop1 = _linear_setup("mobile", seed=1)[0]
    states = stack_trees([pop0, pop1])
    keys = stack_trees([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
    out = run_sweep(states, co, batch_fn, train_fn, pcfg, keys,
                    methods=("local", "oppcl"))
    assert set(out) == {"local", "oppcl"}
    for m, (vf, _) in out.items():
        assert jax.tree.leaves(vf["mule_models"])[0].shape[0] == 2
        seq, _ = run_population(pop1, co, batch_fn, train_fn, pcfg,
                                jax.random.PRNGKey(1), method=m)
        _assert_trees_bitwise(jax.tree.map(lambda l: l[1], vf), seq)


def test_sweep_context_carries_per_seed_data():
    """context leaves stacked [S, ...] reach batch_fn/eval_fn per lane."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile", seed=0)
    states = stack_trees([pop, pop])
    keys = stack_trees([jax.random.PRNGKey(7), jax.random.PRNGKey(7)])
    ctx = {"scale": jnp.array([1.0, 2.0])}

    def ctx_batch_fn(key, t, ctx):
        b = batch_fn(key, t)
        return {"fixed": None,
                "mule": (b["mule"][0] * ctx["scale"], b["mule"][1])}

    def ctx_eval(st, last, ctx):
        return jnp.mean(st["mule_models"]["w"]) + ctx["scale"]

    vf, aux = run_sweep(states, stack_colocations([co, co]), ctx_batch_fn,
                        train_fn, pcfg, keys, eval_every=6,
                        eval_fn=ctx_eval, context=ctx)
    assert np.asarray(aux["evals"]).shape == (2, 3)
    # identical seeds/states, different context -> lanes must differ
    assert not np.allclose(np.asarray(aux["evals"])[0],
                           np.asarray(aux["evals"])[1])
    np.testing.assert_array_equal(aux["eval_steps"], [5, 11, 17])


def test_loop_context_matches_scan_context():
    """The loop parity reference supports the context pytree the scan
    threads to ``batches``, so context-carrying runs are parity-covered."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    ctx = {"scale": jnp.float32(1.7)}

    def ctx_batch_fn(key, t, ctx):
        b = batch_fn(key, t)
        return {"fixed": None,
                "mule": (b["mule"][0] * ctx["scale"], b["mule"][1])}

    key = jax.random.PRNGKey(21)
    final, _ = run_population(pop, co, ctx_batch_fn, train_fn, pcfg, key,
                              context=ctx)
    ref, _ = run_population_loop(pop, co, ctx_batch_fn, train_fn, pcfg, key,
                                 context=ctx)
    _assert_trees_bitwise(final, ref)
    # and the context actually matters: a different scale diverges
    other, _ = run_population_loop(pop, co, ctx_batch_fn, train_fn, pcfg,
                                   key, context={"scale": jnp.float32(0.3)})
    assert not np.array_equal(np.asarray(ref["mule_models"]["w"]),
                              np.asarray(other["mule_models"]["w"]))


def test_jit_cache_no_retrace_on_repeat_call():
    """Second same-shape call must be a cache hit with zero new traces."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    key = jax.random.PRNGKey(1)
    jit_cache_clear()
    run_population(pop, co, batch_fn, train_fn, pcfg, key, method="mlmule")
    s1 = jit_cache_stats()
    assert s1["misses"] == 1 and s1["traces"] == 1
    run_population(pop, co, batch_fn, train_fn, pcfg,
                   jax.random.PRNGKey(2), method="mlmule")
    s2 = jit_cache_stats()
    assert s2["traces"] == 1, "same-shape repeat call retraced"
    assert s2["hits"] == 1
    # a different schedule length is a different program -> one new trace
    co_short = {k: (np.asarray(v)[: T // 2]
                    if np.ndim(v) > 1 and np.shape(v)[0] == T else v)
                for k, v in co.items()}
    run_population(pop, co_short, batch_fn, train_fn, pcfg, key,
                   method="mlmule")
    s3 = jit_cache_stats()
    assert s3["traces"] == 2 and s3["misses"] == 2


# ---------------------------------------------------------------------------
# population churn: activity masks through every engine path
# ---------------------------------------------------------------------------


def _churned_setup(mode="mobile", seed=0):
    from repro.mobility import markov_churn_mask
    pop, co, batch_fn, train_fn, pcfg = _linear_setup(mode, seed=seed)
    co = dict(co)
    co["active"] = markov_churn_mask(900 + seed, T, M,
                                     p_leave=0.2, p_join=0.3)
    assert co["active"].any() and not co["active"].all()
    return pop, co, batch_fn, train_fn, pcfg


@pytest.mark.parametrize("method", METHODS_MOBILE)
def test_churn_scan_matches_loop(method):
    """Masked scan == masked per-step loop, bitwise, for every method."""
    pop, co, batch_fn, train_fn, pcfg = _churned_setup("mobile")
    key = jax.random.PRNGKey(23)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                method=method)
    ref, ref_last = run_population_loop(pop, co, batch_fn, train_fn, pcfg,
                                        key, method=method)
    _assert_trees_bitwise(final, ref)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]),
                                  np.asarray(ref_last))


@pytest.mark.parametrize("method", METHODS_MOBILE)
def test_all_ones_mask_matches_dense_run(method):
    """An explicit all-ones mask is bitwise-identical to no mask at all —
    churn support cannot perturb the dense path."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    key = jax.random.PRNGKey(29)
    dense, daux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                 method=method)
    co_ones = dict(co)
    co_ones["active"] = np.ones_like(np.asarray(co["fixed_id"]), bool)
    masked, maux = run_population(pop, co_ones, batch_fn, train_fn, pcfg,
                                  key, method=method)
    _assert_trees_bitwise(masked, dense)
    np.testing.assert_array_equal(np.asarray(maux["last_fid"]),
                                  np.asarray(daux["last_fid"]))
    # ... and the masked loop reference agrees with the dense loop too
    lref, _ = run_population_loop(pop, co_ones, batch_fn, train_fn, pcfg,
                                  key, method=method)
    dref, _ = run_population_loop(pop, co, batch_fn, train_fn, pcfg, key,
                                  method=method)
    _assert_trees_bitwise(lref, dref)


def test_churn_actually_gates_training():
    """A mule inactive for the whole run keeps its initial model; dense
    and churned runs of the same schedule diverge."""
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    co = dict(co)
    act = np.ones((T, M), bool)
    act[:, 0] = False                       # mule 0 never comes online
    co["active"] = act
    key = jax.random.PRNGKey(31)
    # precondition: ungated, mule 0 WOULD record a nonzero visit — so the
    # last_fid == 0 checks below can only pass through the activity gate,
    # not by coinciding with the init sentinel
    fid = np.asarray(co["fixed_id"])
    dense_last = np.zeros(M, np.int64)
    for t in range(T):
        dense_last = np.where(fid[t] >= 0, fid[t], dense_last)
    assert dense_last[0] != 0, "schedule no longer distinguishes the gate"
    for method in ("mlmule", "local", "gossip"):
        final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                    method=method)
        np.testing.assert_array_equal(
            np.asarray(final["mule_models"]["w"][0]),
            np.asarray(pop["mule_models"]["w"][0]),
            f"{method}: inactive mule's model changed")
        assert int(np.asarray(aux["last_fid"])[0]) == 0, \
            f"{method}: inactive mule recorded a visit"
    dense, _ = run_population(pop, co | {"active": np.ones((T, M), bool)},
                              batch_fn, train_fn, pcfg, key)
    churned, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    assert not np.array_equal(np.asarray(dense["mule_models"]["w"]),
                              np.asarray(churned["mule_models"]["w"]))


def test_churn_sweep_matches_sequential_bitwise():
    """Per-seed churn masks vmap with the rest of the colocation stack."""
    seeds = [0, 1, 2]
    setups = [_churned_setup("mobile", seed=s) for s in seeds]
    _, _, batch_fn, train_fn, pcfg = setups[0]
    keys = [jax.random.PRNGKey(300 + s) for s in seeds]
    finals = [run_population(pop, co, batch_fn, train_fn, pcfg, key,
                             method="oppcl")[0]
              for (pop, co, _, _, _), key in zip(setups, keys)]
    states = stack_trees([s[0] for s in setups])
    cos = stack_colocations([s[1] for s in setups])
    assert "active" in cos and cos["active"].shape == (3, T, M)
    vf, _ = run_sweep(states, cos, batch_fn, train_fn, pcfg,
                      stack_trees(keys), methods="oppcl")
    for i in range(len(seeds)):
        _assert_trees_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i])


def test_jit_cache_churn_regression():
    """Masks are data: repeat same-shape churn runs perform ZERO retraces
    (dense and churned runs share one compiled replay); a changed mask
    shape is a cache miss — a new entry, never a retrace of an existing
    one."""
    from repro.mobility import duty_cycle_mask, markov_churn_mask
    pop, co, batch_fn, train_fn, pcfg = _linear_setup("mobile")
    key = jax.random.PRNGKey(1)
    jit_cache_clear()
    run_population(pop, co, batch_fn, train_fn, pcfg, key)    # dense trace
    assert jit_cache_stats()["traces"] == 1
    co_a = dict(co, active=markov_churn_mask(1, T, M))
    co_b = dict(co, active=duty_cycle_mask(2, T, M, period=6))
    run_population(pop, co_a, batch_fn, train_fn, pcfg, key)
    run_population(pop, co_b, batch_fn, train_fn, pcfg,
                   jax.random.PRNGKey(2))
    s = jit_cache_stats()
    assert s["traces"] == 1, "same-shape churn run retraced"
    assert s["hits"] == 2 and s["misses"] == 1

    # a different schedule shape (new mask shape included) is a miss ...
    half = T // 2
    co_short = {k: (np.asarray(v)[:half]
                    if np.ndim(v) > 1 and np.shape(v)[0] == T else v)
                for k, v in co_a.items()}
    run_population(pop, co_short, batch_fn, train_fn, pcfg, key)
    s = jit_cache_stats()
    assert s["traces"] == 2 and s["misses"] == 2
    # ... that coexists with the old entry: both shapes now hit
    run_population(pop, co_a, batch_fn, train_fn, pcfg, key)
    run_population(pop, co_short, batch_fn, train_fn, pcfg, key)
    s = jit_cache_stats()
    assert s["traces"] == 2, "an existing entry was retraced"
    assert s["hits"] == 4
