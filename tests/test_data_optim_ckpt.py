"""Data partitioners, synthetic datasets, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.data import (dirichlet_partition, iid_partition, make_image_dataset,
                        make_imu_dataset, make_lm_dataset, shards_partition)
from repro.data.partition import train_test_split
from repro.optim import adam, clip_by_global_norm, cosine_schedule, sgd


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_image_dataset_structure():
    x, sup, sub = make_image_dataset(0, n_per_sub=10, n_super=4, n_sub=5, size=16)
    assert x.shape == (200, 16, 16, 3)
    assert set(sup.tolist()) == set(range(4))
    assert set(sub.tolist()) == set(range(20))
    assert (sub // 5 == sup).all()          # hierarchy consistent


def test_imu_dataset_matches_table2_sparsity():
    x, y, loc = make_imu_dataset(0, n_per_cell=5)
    assert x.shape[1:] == (128, 6)
    # dance (class 2) only occurs at locations 6, 7 (paper Table 2)
    assert set(loc[y == 2].tolist()) == {6, 7}
    # bike repair absent from location 3
    assert 3 not in set(loc[y == 0].tolist())


def test_lm_dataset():
    seqs, spaces = make_lm_dataset(0, n_seqs=4, seq_len=64, vocab=128)
    assert seqs.shape == (4, 64) and seqs.max() < 128


@settings(max_examples=10, deadline=None)
@given(alpha=st.sampled_from([0.001, 0.01, 0.1, 1.0]), seed=st.integers(0, 50))
def test_dirichlet_partition_covers_all(alpha, seed):
    labels = np.repeat(np.arange(10), 50)
    parts = dirichlet_partition(labels, 8, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) >= len(labels) * 0.95   # top-ups may duplicate a few
    for p in parts:
        assert len(p) >= 8


def test_dirichlet_alpha_controls_concentration():
    labels = np.repeat(np.arange(20), 100)

    def mean_classes(alpha):
        parts = dirichlet_partition(labels, 8, alpha, seed=0)
        return np.mean([len(set(labels[p].tolist())) for p in parts])

    assert mean_classes(0.001) < mean_classes(10.0)


def test_shards_partition_paper_structure():
    x, sup, sub = make_image_dataset(0, n_per_sub=10, n_super=20, n_sub=5)
    out = shards_partition(sup, sub)
    assert len(out["space_idx"]) == 8
    a0 = set(out["area_supers"][0])
    a1 = set(out["area_supers"][1])
    assert len(a0) == 10 and len(a1) == 10 and not (a0 & a1)
    # each space holds exactly one sub-class per super of its area
    idx = out["space_idx"][(0, 2)]
    subs_here = set(sub[idx].tolist())
    supers_here = set(sup[idx].tolist())
    assert supers_here == a0
    assert len(subs_here) == 10            # one sub per super
    # general knowledge = the 5th sub-class
    gidx = out["general_idx"][(0, 2)]
    assert all(s % 5 == 4 for s in sub[gidx])


def test_train_test_split_disjoint():
    tr, te = train_test_split(np.arange(100), 0.2, seed=1)
    assert len(te) == 20 and not set(tr) & set(te)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9), adam(0.05)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(120):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(params, g, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_cosine_schedule_shape():
    sch = cosine_schedule(1.0, 100, warmup=10)
    assert float(sch(jnp.int32(0))) < 0.11
    assert abs(float(sch(jnp.int32(10))) - 1.0) < 1e-5
    assert float(sch(jnp.int32(100))) < 1e-3


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm(seed, max_norm):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (17,)) * 5}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.linalg.norm(clipped["a"]))
    assert new_norm <= max_norm * 1.001
    if float(norm) <= max_norm:   # no-op below threshold
        np.testing.assert_allclose(np.asarray(clipped["a"]), np.asarray(g["a"]),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3)},
            "ts": jnp.array([1.0, 2.0])}
    p = save_checkpoint(str(tmp_path), 7, tree, metadata={"mule_ts": [1, 2]})
    assert latest_checkpoint(str(tmp_path)) == p
    restored, meta = restore_checkpoint(p, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_allclose(np.asarray(restored["layer"]["w"]),
                               np.asarray(tree["layer"]["w"]))
    assert meta["step"] == 7 and meta["mule_ts"] == [1, 2]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((2, 3))}
    p = save_checkpoint(str(tmp_path), 0, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(p, {"w": jnp.zeros((3, 3))})
