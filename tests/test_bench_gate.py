"""Gate self-test: the perf ratchet must be proven to trip before CI
trusts it. Synthetic artifacts pin fail/pass/raise behaviour for every
branch of ``benchmarks.bench_gate``; the committed artifacts themselves are
schema-validated here too (the tier-1 half of the gate)."""
import json

import pytest

from benchmarks import bench_gate
from benchmarks.bench_gate import (ARTIFACTS, GateSchemaError, check_committed,
                                   gate_all, gate_artifact, validate)

_PROTO = {float: 1.0, int: 0, bool: True, list: [], dict: {}}


def _payload(name, headline=None, **overrides):
    """Minimal schema-valid artifact for one family."""
    schema = ARTIFACTS[name]
    p = {"bench": schema.bench, "config": {"backend": "cpu"}}
    for key, typ in schema.required.items():
        p[key] = _PROTO[typ]
    if headline is not None:
        p[schema.headline] = headline
    p.update(overrides)
    return p


def _write_all(directory, headlines=None):
    headlines = headlines or {}
    for name in ARTIFACTS:
        (directory / name).write_text(
            json.dumps(_payload(name, headline=headlines.get(name))))


# ---------------------------------------------------------------------------
# regression gating: fail / pass / direction / slack
# ---------------------------------------------------------------------------


def test_regression_trips():
    base = _payload("BENCH_sweep.json", headline=6.5)
    fresh = _payload("BENCH_sweep.json", headline=5.0)     # -23% > 10%
    r = gate_artifact("BENCH_sweep.json", base, fresh)
    assert not r.ok
    assert "dropped" in r.reason
    assert "FAIL" in r.row()


def test_within_threshold_passes():
    base = _payload("BENCH_sweep.json", headline=6.5)
    fresh = _payload("BENCH_sweep.json", headline=6.0)     # -7.7% < 10%
    r = gate_artifact("BENCH_sweep.json", base, fresh)
    assert r.ok


def test_improvement_always_passes():
    base = _payload("BENCH_encounter.json", headline=1.8)
    fresh = _payload("BENCH_encounter.json", headline=9.9)
    r = gate_artifact("BENCH_encounter.json", base, fresh)
    assert r.ok
    assert "improved or held" in r.reason


def test_unchanged_passes():
    base = _payload("BENCH_distributed.json", headline=5.9)
    assert gate_artifact("BENCH_distributed.json", base, dict(base)).ok


def test_lower_is_better_direction():
    # churn overhead: RISING is the regression
    base = _payload("BENCH_churn.json", headline=5.0)
    worse = _payload("BENCH_churn.json", headline=9.0)     # > 5*1.1 + 2.0
    better = _payload("BENCH_churn.json", headline=1.0)
    assert not gate_artifact("BENCH_churn.json", base, worse).ok
    assert gate_artifact("BENCH_churn.json", base, better).ok


def test_abs_slack_shields_near_zero_metrics():
    # 10% of a 0.2% overhead is pure noise; the 2-point absolute slack
    # means only a real rise (past ~2.2) trips
    base = _payload("BENCH_churn.json", headline=0.2)
    noisy = _payload("BENCH_churn.json", headline=2.0)
    real = _payload("BENCH_churn.json", headline=3.0)
    assert gate_artifact("BENCH_churn.json", base, noisy).ok
    assert not gate_artifact("BENCH_churn.json", base, real).ok


def test_roofline_slack_around_unity():
    # tuned_speedup_vs_default sits near 1.0 when the defaults are already
    # optimal; 0.05 absolute slack keeps jitter out, a real drop still trips
    base = _payload("BENCH_roofline.json", headline=1.0)
    jitter = _payload("BENCH_roofline.json", headline=0.93)
    real = _payload("BENCH_roofline.json", headline=0.8)
    assert gate_artifact("BENCH_roofline.json", base, jitter).ok
    assert not gate_artifact("BENCH_roofline.json", base, real).ok


def test_extra_headline_regression_trips():
    # BENCH_encounter gates ring_vs_host alongside the primary headline:
    # a held primary with a collapsed ring ratio must still fail, and the
    # failure reason must name the extra metric
    base = _payload("BENCH_encounter.json", headline=2.0, ring_vs_host=6.0)
    fresh = _payload("BENCH_encounter.json", headline=2.0, ring_vs_host=0.5)
    r = gate_artifact("BENCH_encounter.json", base, fresh)
    assert not r.ok
    assert "ring_vs_host" in r.reason
    # both held -> pass; extra improved + primary held -> pass
    assert gate_artifact("BENCH_encounter.json", base, dict(base)).ok
    better = _payload("BENCH_encounter.json", headline=2.0, ring_vs_host=9.0)
    assert gate_artifact("BENCH_encounter.json", base, better).ok


def test_extra_headline_in_describe():
    assert "ring_vs_host" in ARTIFACTS["BENCH_encounter.json"].describe()


def test_threshold_is_configurable():
    base = _payload("BENCH_sweep.json", headline=10.0)
    fresh = _payload("BENCH_sweep.json", headline=8.0)
    assert not gate_artifact("BENCH_sweep.json", base, fresh, threshold=0.1).ok
    assert gate_artifact("BENCH_sweep.json", base, fresh, threshold=0.25).ok


# ---------------------------------------------------------------------------
# schema validation: raise on anything malformed
# ---------------------------------------------------------------------------


def test_unknown_artifact_raises():
    with pytest.raises(GateSchemaError, match="unknown artifact"):
        validate("BENCH_nope.json", {})


def test_non_dict_payload_raises():
    with pytest.raises(GateSchemaError, match="not an object"):
        validate("BENCH_sweep.json", [1, 2, 3])


def test_wrong_bench_entry_point_raises():
    p = _payload("BENCH_sweep.json", bench="engine_micro.run_churn_bench")
    with pytest.raises(GateSchemaError, match="bench="):
        validate("BENCH_sweep.json", p)


def test_missing_required_key_raises():
    p = _payload("BENCH_sweep.json")
    del p["speedup_vs_sequential"]
    with pytest.raises(GateSchemaError, match="speedup_vs_sequential"):
        validate("BENCH_sweep.json", p)


def test_missing_config_raises():
    p = _payload("BENCH_sweep.json")
    del p["config"]
    with pytest.raises(GateSchemaError, match="config"):
        validate("BENCH_sweep.json", p)


def test_mistyped_value_raises():
    p = _payload("BENCH_sweep.json", headline="fast")
    with pytest.raises(GateSchemaError, match="expected float"):
        validate("BENCH_sweep.json", p)


def test_bool_is_not_a_number():
    # json.load never yields bool for a number, but a buggy producer can:
    # True must not satisfy an int/float key (bool is an int subclass)
    p = _payload("BENCH_sweep.json", retraces_second_call=True)
    with pytest.raises(GateSchemaError, match="retraces_second_call"):
        validate("BENCH_sweep.json", p)


def test_gate_validates_both_sides():
    good = _payload("BENCH_sweep.json", headline=6.0)
    bad = _payload("BENCH_sweep.json")
    del bad["vmapped_warm_s"]
    with pytest.raises(GateSchemaError):
        gate_artifact("BENCH_sweep.json", bad, good)
    with pytest.raises(GateSchemaError):
        gate_artifact("BENCH_sweep.json", good, bad)


# ---------------------------------------------------------------------------
# committed artifacts: the tier-1 acceptance criterion
# ---------------------------------------------------------------------------


def test_committed_artifacts_validate():
    """Every committed BENCH_*.json — including BENCH_roofline.json —
    parses and matches its schema; this is what tier-1 CI runs."""
    assert check_committed() == sorted(ARTIFACTS)


def test_every_headline_is_a_required_key():
    for name, schema in ARTIFACTS.items():
        assert schema.headline in schema.required, name


# ---------------------------------------------------------------------------
# CLI: exit codes are the CI contract (0 pass, 1 regression, 2 schema)
# ---------------------------------------------------------------------------


def test_cli_check_committed_exits_zero(capsys):
    assert bench_gate.main(["--check-committed"]) == 0
    out = capsys.readouterr().out
    assert "BENCH_roofline.json" in out


def test_cli_gate_pass_and_regression(tmp_path, capsys):
    baseline, fresh = tmp_path / "base", tmp_path / "fresh"
    baseline.mkdir(), fresh.mkdir()
    _write_all(baseline, {"BENCH_sweep.json": 6.5})
    _write_all(fresh, {"BENCH_sweep.json": 6.4})
    argv = ["--baseline", str(baseline), "--fresh", str(fresh)]
    assert bench_gate.main(argv) == 0
    _write_all(fresh, {"BENCH_sweep.json": 3.0})           # regress
    assert bench_gate.main(argv) == 1
    captured = capsys.readouterr()
    assert "FAIL" in captured.out
    assert "ratchet" in captured.err


def test_cli_single_artifact_filter(tmp_path):
    baseline, fresh = tmp_path / "base", tmp_path / "fresh"
    baseline.mkdir(), fresh.mkdir()
    _write_all(baseline, {"BENCH_sweep.json": 6.5})
    _write_all(fresh, {"BENCH_sweep.json": 3.0})
    argv = ["--baseline", str(baseline), "--fresh", str(fresh)]
    # gating only the un-regressed artifact passes; the regressed one fails
    assert bench_gate.main(argv + ["--artifact", "BENCH_churn.json"]) == 0
    assert bench_gate.main(argv + ["--artifact", "BENCH_sweep.json"]) == 1


def test_cli_schema_error_exits_two(tmp_path, capsys):
    baseline, fresh = tmp_path / "base", tmp_path / "fresh"
    baseline.mkdir(), fresh.mkdir()
    _write_all(baseline)
    _write_all(fresh)
    (fresh / "BENCH_sweep.json").write_text("{truncated")
    assert bench_gate.main(["--baseline", str(baseline),
                            "--fresh", str(fresh)]) == 2
    assert "SCHEMA ERROR" in capsys.readouterr().err


def test_cli_missing_artifact_exits_two(tmp_path):
    baseline, fresh = tmp_path / "base", tmp_path / "fresh"
    baseline.mkdir(), fresh.mkdir()
    _write_all(baseline)
    _write_all(fresh)
    (fresh / "BENCH_distributed.json").unlink()
    assert bench_gate.main(["--baseline", str(baseline),
                            "--fresh", str(fresh)]) == 2


def test_gate_all_reports_every_artifact(tmp_path):
    baseline, fresh = tmp_path / "base", tmp_path / "fresh"
    baseline.mkdir(), fresh.mkdir()
    _write_all(baseline)
    _write_all(fresh)
    results = gate_all(str(baseline), str(fresh))
    assert [r.name for r in results] == sorted(ARTIFACTS)
    assert all(r.ok for r in results)
