"""Baseline algorithms: semantic checks on toy problems."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import (CFLState, cfl_round, fedas_round, fedavg_round,
                             gossip_step, local_step, oppcl_step)
from repro.baselines.cfl import cfl_client_models


def _toy_setup(n_clients=8, d=6, seed=0):
    """Linear regression clients; targets differ per cluster."""
    rng = np.random.default_rng(seed)
    w_true = {0: rng.normal(size=d), 1: -rng.normal(size=d)}
    xs, ys, cluster = [], [], []
    for c in range(n_clients):
        cl = c % 2
        x = rng.normal(size=(32, d))
        y = x @ w_true[cl]
        xs.append(x)
        ys.append(y)
        cluster.append(cl)
    return (jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
            np.array(cluster))


def _train_fn(params, batch, key):
    x, y = batch

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    g = jax.grad(loss)(params)
    return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)


def _loss_of(params, x, y):
    return float(jnp.mean((x @ params["w"] - y) ** 2))


def test_fedavg_reduces_loss_iid():
    xs, ys, cl = _toy_setup()
    # make IID: same true w
    ys = jnp.einsum("cnd,d->cn", xs, jnp.ones(6))
    model = {"w": jnp.zeros(6)}
    sizes = jnp.full((8,), 32.0)
    l0 = np.mean([_loss_of(model, xs[c], ys[c]) for c in range(8)])
    for r in range(30):
        model = fedavg_round(model, (xs, ys), sizes, _train_fn,
                             jax.random.PRNGKey(r), local_steps=2)
    l1 = np.mean([_loss_of(model, xs[c], ys[c]) for c in range(8)])
    assert l1 < 0.2 * l0


def test_cfl_splits_bimodal_clients():
    xs, ys, cl = _toy_setup()
    state = CFLState(clusters=[np.arange(8)], models=[{"w": jnp.zeros(6)}],
                     eps1=1e9, eps2=0.0)  # force split check every round
    sizes = jnp.full((8,), 32.0)
    for r in range(12):
        state = cfl_round(state, (xs, ys), sizes, _train_fn,
                          jax.random.PRNGKey(r), local_steps=2)
        if len(state.clusters) > 1:
            break
    assert len(state.clusters) >= 2
    # the split should separate the two ground-truth clusters
    got = state.clusters[0]
    purity = max(np.mean(cl[got] == 0), np.mean(cl[got] == 1))
    assert purity >= 0.75
    stacked = cfl_client_models(state, 8)
    assert stacked["w"].shape == (8, 6)


def test_fedas_keeps_personal_parts_local():
    xs, ys, _ = _toy_setup()
    glob = {"backbone": jnp.zeros(6), "fc2": jnp.zeros(3)}
    clients = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (8,) + l.shape).copy(), glob)
    clients["fc2"] = jnp.arange(24, dtype=jnp.float32).reshape(8, 3)

    def train(params, batch, key):
        x, y = batch
        g = jax.grad(lambda p: jnp.mean((x @ p["backbone"] - y) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    sizes = jnp.full((8,), 32.0)
    new_glob, new_clients = fedas_round(glob, clients, (xs, ys), sizes, train,
                                        jax.random.PRNGKey(0))
    # fc2 (personal) unchanged per client and not pushed into global
    np.testing.assert_allclose(np.asarray(new_clients["fc2"]),
                               np.asarray(clients["fc2"]))
    np.testing.assert_allclose(np.asarray(new_glob["fc2"]),
                               np.asarray(glob["fc2"]))
    # backbone did aggregate
    assert float(jnp.sum(jnp.abs(new_glob["backbone"]))) > 0


def test_gossip_and_oppcl_step():
    xs, ys, _ = _toy_setup()
    models = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 6))}
    pos = jnp.array([[0.1, 0.1]] * 4 + [[0.9, 0.9]] * 4)
    area = jnp.zeros(8, jnp.int32)
    out_g = gossip_step(models, pos, area, (xs, ys), _train_fn,
                        jax.random.PRNGKey(1), radius=0.05)
    # within-group models move toward each other
    var_before = float(jnp.var(models["w"][:4], axis=0).mean())
    var_after = float(jnp.var(out_g["w"][:4], axis=0).mean())
    assert var_after < var_before
    out_o = oppcl_step(models, pos, area, (xs, ys), _train_fn,
                       jax.random.PRNGKey(2), radius=0.05)
    assert jax.tree.leaves(out_o)[0].shape == (8, 6)


def test_gossip_respects_area_isolation():
    models = {"w": jnp.stack([jnp.zeros(3), jnp.ones(3)])}
    pos = jnp.array([[0.5, 0.5], [0.5, 0.5]])
    area = jnp.array([0, 1], jnp.int32)  # same spot, different areas
    out = gossip_step(models, pos, area, (jnp.zeros((2, 4, 3)), jnp.zeros((2, 4))),
                      lambda p, b, k: p, jax.random.PRNGKey(0), radius=0.2)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(models["w"]))


def test_local_only_moves_independently():
    xs, ys, _ = _toy_setup()
    models = {"w": jnp.zeros((8, 6))}
    out = local_step(models, (xs, ys), _train_fn, jax.random.PRNGKey(0))
    assert float(jnp.sum(jnp.abs(out["w"]))) > 0
