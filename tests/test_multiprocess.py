"""Multi-process bring-up: ``launch.multiprocess`` plumbing plus the
cluster-parity pin.

The cheap tests cover the pieces that must hold in any single process —
mesh validation that names both the requested shape and the device pool,
per-process jit-cache attribution, the placement helpers degrading to
plain device commits, and ``ordered_psum`` agreeing bitwise with
``lax.psum`` where the fold is trivial. The slow test is the actual
tentpole pin: a 2-process x 2-device ``jax.distributed`` CPU cluster
replaying the streamed engine (mid-run re-bucketing swaps included) must
produce final mule models bitwise identical to the same mesh shape in
one process, for both the paper method and the gossip baseline.
"""
import hashlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh, make_mule_mesh
from repro.launch.multiprocess import (ENV_COORDINATOR, ENV_NUM_PROCESSES,
                                       ENV_PROCESS_ID, host_replicated,
                                       initialize_from_env,
                                       local_cluster_env, pick_free_port,
                                       put_global, put_global_tree,
                                       spawn_local_cluster)

from conftest import run_with_devices


# ---------------------------------------------------------------------------
# cheap: single-process plumbing
# ---------------------------------------------------------------------------


def test_mule_mesh_validation_names_both_numbers():
    with pytest.raises(ValueError) as e:
        make_mule_mesh(4, 16)
    msg = str(e.value)
    assert "needs 64 devices" in msg
    assert f"jax.device_count()={jax.device_count()}" in msg
    assert "process(es)" in msg


def test_host_mesh_validation_names_both_numbers():
    with pytest.raises(ValueError) as e:
        make_host_mesh(data=8, model=8)
    assert "needs 64 devices" in str(e.value)
    assert f"jax.device_count()={jax.device_count()}" in str(e.value)


def test_jit_cache_stats_per_process_prefix():
    from repro.scenarios import jit_cache_stats
    plain = jit_cache_stats()
    pref = jit_cache_stats(per_process=True)
    prefix = f"p{jax.process_index()}/"
    assert set(pref) == {prefix + k for k in plain}
    for k, v in plain.items():
        assert pref[prefix + k] == v


def test_local_cluster_env_sets_the_triple():
    env = local_cluster_env(1, 3, "127.0.0.1:9999", 4, base_env={})
    assert env[ENV_COORDINATOR] == "127.0.0.1:9999"
    assert env[ENV_NUM_PROCESSES] == "3"
    assert env[ENV_PROCESS_ID] == "1"
    assert "xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert env["JAX_PLATFORMS"] == "cpu"
    # an existing forced device count is left alone (the caller set it)
    env2 = local_cluster_env(
        0, 2, "c:1", 4,
        base_env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    assert "count=8" in env2["XLA_FLAGS"] and "count=4" not in env2["XLA_FLAGS"]


def test_initialize_from_env_is_noop_without_the_triple():
    assert initialize_from_env(env={}) is False


def test_pick_free_port_is_bindable():
    import socket
    port = pick_free_port()
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", port))


def test_put_global_single_process_roundtrip():
    from jax.sharding import PartitionSpec as P
    mesh = make_mule_mesh(1, 1)
    x = np.arange(12, dtype=np.float32).reshape(6, 2)
    sharded = put_global(x, mesh, P("data"))
    np.testing.assert_array_equal(np.asarray(sharded), x)
    replicated = put_global(x, mesh, P())
    np.testing.assert_array_equal(np.asarray(replicated), x)
    scalar = put_global(np.float32(3.5), mesh, P())
    assert float(scalar) == 3.5
    tree = put_global_tree({"a": x, "b": x[:, 0]}, mesh,
                           {"a": P("data"), "b": P()})
    np.testing.assert_array_equal(np.asarray(tree["a"]), x)
    np.testing.assert_array_equal(np.asarray(tree["b"]), x[:, 0])
    # fully-addressable arrays read straight back
    np.testing.assert_array_equal(host_replicated(replicated), x)


def test_ordered_psum_matches_psum_on_one_shard():
    """Where the rank-order fold is trivial (one shard) the deterministic
    reduction must be bitwise the raw ``lax.psum`` it replaced."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import ordered_pmean, ordered_psum

    mesh = make_mule_mesh(1, 1)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 3))

    def both(v):
        return (ordered_psum(v, "data"), jax.lax.psum(v, "data"),
                ordered_pmean(v, "data"), jax.lax.pmean(v, "data"))

    a, b, c, d = jax.jit(shard_map(
        both, mesh=mesh, in_specs=(P("data"),),
        out_specs=(P("data"),) * 4, check_rep=False))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))


# ---------------------------------------------------------------------------
# slow: the cluster-parity pin
# ---------------------------------------------------------------------------


_PARITY_CODE = """
import hashlib, os, sys
from repro.launch.multiprocess import initialize_from_env
initialize_from_env()
import jax, numpy as np
from jax.experimental import multihost_utils
sys.path.insert(0, os.path.join(os.getcwd(), "tests"))
from conftest import linear_population_setup
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.mobility import compact_colocation
from repro.scenarios import get_scenario, run_population_streamed

M, T = 8, 96
assert jax.device_count() == 4, jax.device_count()
mesh = jax.make_mesh((1, 4), ("pod", "data"))
for method in ("mlmule", "gossip"):
    pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    co = get_scenario("multi_area_migratory").colocation(0, M, T)
    dcfg = DistributedConfig(pop=pcfg, rebucket_every=16,
                             rebucket_threshold=0.1)
    st, aux = run_population_streamed(
        to_distributed_state(pop, dcfg), compact_colocation(co), batch_fn,
        train_fn, pcfg, jax.random.PRNGKey(7), n_steps=T, chunk_len=16,
        method=method, donate=False, mesh=mesh, dcfg=dcfg)
    w = multihost_utils.process_allgather(st["mule_models"]["w"],
                                          tiled=True)
    w = np.ascontiguousarray(np.asarray(w, np.float32))
    print("RESULT", method, aux["rebucket"]["swaps"],
          hashlib.sha256(w.tobytes()).hexdigest())
"""


def _parse_parity(stdout: str) -> dict:
    out = {}
    for line in stdout.splitlines():
        if line.startswith("RESULT "):
            _, method, swaps, digest = line.split()
            out[method] = (int(swaps), digest)
    assert set(out) == {"mlmule", "gossip"}, stdout
    return out


@pytest.mark.slow
def test_multiprocess_streamed_matches_single_process_bitwise():
    """2 processes x 2 devices == 1 process x 4 devices, bitwise, across
    re-bucketing swaps, for the paper method and the gossip baseline.

    Same (1, 4) mule mesh on both sides, so the shard_map program is
    identical — the pin is that crossing a process boundary (gloo
    collectives, per-process placement, the psum'd global argsort in the
    swap path) changes nothing: every rank's process-allgathered final
    weights hash to the single-process digest.
    """
    import os
    ref = _parse_parity(run_with_devices(_PARITY_CODE, n_devices=4))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    results = spawn_local_cluster(
        [sys.executable, "-c", _PARITY_CODE], num_processes=2,
        devices_per_process=2, base_env=env, timeout=600)
    for pid, res in enumerate(results):
        assert res.returncode == 0, \
            f"rank {pid} failed:\n{res.stdout}"
        got = _parse_parity(res.stdout)
        for method in ("mlmule", "gossip"):
            swaps_ref, digest_ref = ref[method]
            swaps, digest = got[method]
            assert swaps_ref >= 1, \
                f"{method}: drift never tripped a swap (weak workload)"
            assert swaps == swaps_ref, (method, pid, swaps, swaps_ref)
            assert digest == digest_ref, \
                f"{method}: rank {pid} diverged from single-process run"
