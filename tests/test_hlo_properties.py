"""Property tests for the scan-aware HLO parsers: generated dot / conv /
collective / while snippets with analytically known FLOPs, bytes and trip
counts must round-trip through ``hlo_analysis.analyze_hlo`` EXACTLY — the
analyzer's regexes are pinned against the HLO text grammar here, not
against whatever today's XLA happens to emit."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # tier-1 container: fixed-seed sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.launch.dtypes import DTYPE_BYTES
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import collective_bytes

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# every sized dtype the shared table knows (token is unsized, no array shape)
_SIZED_DTYPES = sorted(d for d, b in DTYPE_BYTES.items() if b > 0)


def _dot_module(m, k, n):
    return f"""HloModule dot

ENTRY %main (a: f32[{m},{k}], b: f32[{k},{n}]) -> f32[{m},{n}] {{
  %a = f32[{m},{k}]{{1,0}} parameter(0)
  %b = f32[{k},{n}]{{1,0}} parameter(1)
  ROOT %d = f32[{m},{n}]{{1,0}} dot(%a, %b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""


def _conv_module(h, w, kh, kw, cin, cout):
    oh, ow = h - kh + 1, w - kw + 1
    return f"""HloModule conv

ENTRY %main (in: f32[1,{h},{w},{cin}], kern: f32[{kh},{kw},{cin},{cout}]) -> f32[1,{oh},{ow},{cout}] {{
  %in = f32[1,{h},{w},{cin}]{{3,2,1,0}} parameter(0)
  %kern = f32[{kh},{kw},{cin},{cout}]{{3,2,1,0}} parameter(1)
  ROOT %conv = f32[1,{oh},{ow},{cout}]{{3,2,1,0}} convolution(%in, %kern), window={{size={kh}x{kw}}}, dim_labels=b01f_01io->b01f
}}
"""


def _coll_module(kind, n):
    attr = ("source_target_pairs={{0,1}},{{1,0}}"
            if kind == "collective-permute" else "replica_groups={}")
    return f"""HloModule coll

ENTRY %main (p: f32[{n}]) -> f32[{n}] {{
  %p = f32[{n}]{{0}} parameter(0)
  ROOT %c = f32[{n}]{{0}} {kind}(%p), {attr}
}}
"""


def _while_module(n, trip, body_extra=""):
    """Counted loop: body does one [n,n]x[n,n] dot per iteration."""
    state = f"(s32[], f32[{n},{n}])"
    return f"""HloModule loop

%body (prev: {state}) -> {state} {{
  %prev = {state} parameter(0)
  %i = s32[] get-tuple-element(%prev), index=0
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %x = f32[{n},{n}]{{1,0}} get-tuple-element(%prev), index=1
  %d = f32[{n},{n}]{{1,0}} dot(%x, %x), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
{body_extra}  ROOT %t = {state} tuple(%ni, %d)
}}

%cond (cp: {state}) -> pred[] {{
  %cp = {state} parameter(0)
  %ci = s32[] get-tuple-element(%cp), index=0
  %limit = s32[] constant({trip})
  ROOT %lt = pred[] compare(%ci, %limit), direction=LT
}}

ENTRY %main (x0: f32[{n},{n}]) -> {state} {{
  %x0 = f32[{n},{n}]{{1,0}} parameter(0)
  %zero = s32[] constant(0)
  %init = {state} tuple(%zero, %x0)
  ROOT %w = {state} while(%init), condition=%cond, body=%body
}}
"""


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
def test_dot_flops_and_bytes_exact(m, k, n):
    r = analyze_hlo(_dot_module(m, k, n))
    assert r.flops == 2 * m * k * n
    # result + both operands, f32
    assert r.bytes == 4 * (m * n + m * k + k * n)
    assert r.coll_bytes == 0


@settings(max_examples=30, deadline=None)
@given(h=st.integers(4, 12), w=st.integers(4, 12),
       kh=st.integers(1, 3), kw=st.integers(1, 3),
       cin=st.integers(1, 8), cout=st.integers(1, 8))
def test_conv_flops_exact(h, w, kh, kw, cin, cout):
    oh, ow = h - kh + 1, w - kw + 1
    r = analyze_hlo(_conv_module(h, w, kh, kw, cin, cout))
    # 2 * output elements * kernel MACs per output element
    assert r.flops == 2 * (oh * ow * cout) * (kh * kw * cin)
    assert r.bytes == 4 * (oh * ow * cout + h * w * cin
                           + kh * kw * cin * cout)


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(_COLLECTIVES), n=st.integers(1, 4096))
def test_collective_bytes_exact(kind, n):
    hlo = _coll_module(kind, n)
    r = analyze_hlo(hlo)
    assert r.coll[kind] == 4 * n             # operand bytes, resolved via sym
    assert r.coll_bytes == 4 * n
    assert r.bytes == 8 * n                  # result + operand
    assert r.flops == 0
    # the roofline-side parser agrees on the wire bytes
    assert collective_bytes(hlo)[kind] == 4 * n


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 24), trip=st.integers(1, 200))
def test_while_trip_count_multiplies_exactly(n, trip):
    r = analyze_hlo(_while_module(n, trip))
    assert r.flops == trip * 2 * n ** 3
    # per iteration: add result (4) + dot result/operands (12 n^2 bytes)
    assert r.bytes == trip * (4 + 12 * n * n)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), trip=st.integers(1, 100))
def test_while_multiplies_collectives_too(n, trip):
    extra = (f"  %ar = f32[{n},{n}]{{1,0}} all-reduce(%d), "
             "replica_groups={}\n")
    r = analyze_hlo(_while_module(n, trip, body_extra=extra))
    assert r.coll["all-reduce"] == trip * 4 * n * n
    assert r.flops == trip * 2 * n ** 3


@settings(max_examples=30, deadline=None)
@given(dtype=st.sampled_from(_SIZED_DTYPES), n=st.integers(1, 1024))
def test_every_known_dtype_prices_exactly(dtype, n):
    hlo = f"""HloModule dt

ENTRY %main (p: {dtype}[{n}]) -> {dtype}[{n}] {{
  %p = {dtype}[{n}]{{0}} parameter(0)
  ROOT %c = {dtype}[{n}]{{0}} copy(%p)
}}
"""
    r = analyze_hlo(hlo)
    # copy counts result + operand through the one shared dtype table
    assert r.bytes == 2 * n * DTYPE_BYTES[dtype]
