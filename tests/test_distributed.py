"""Distributed engine + sharded MoE: multi-device subprocess tests."""
import pytest

# shared by the scan-engine tests below: a linear population + the
# stacking/parity helpers, on a real (2, 4) pod x data mesh
_SCAN_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.population import PopulationConfig, init_population
from repro.core.freshness import FreshnessConfig
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.scenarios import (run_population, run_population_distributed,
                             run_population_distributed_loop,
                             run_sweep_distributed, stack_colocations,
                             stack_trees, walk_colocation)

F, M, T = 4, 16, 12
mesh = jax.make_mesh((2, 4), ("pod", "data"))

def linear_setup(mode, seed=0, **fresh_kw):
    n = F if mode == "fixed" else M
    X = jax.random.normal(jax.random.PRNGKey(50 + seed), (n, 12, 5))
    Y = jax.random.normal(jax.random.PRNGKey(60 + seed), (n, 12))
    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    def batch_fn(key, t):
        idx = jax.random.randint(key, (n, 4), 0, X.shape[1])
        b = (jnp.take_along_axis(X, idx[:, :, None], 1),
             jnp.take_along_axis(Y, idx, 1))
        return ({"fixed": b, "mule": None} if mode == "fixed"
                else {"fixed": None, "mule": b})
    pcfg = PopulationConfig(mode=mode, n_fixed=F, n_mules=M,
                            freshness=FreshnessConfig(**fresh_kw))
    pop = init_population(jax.random.PRNGKey(seed),
                          lambda k: {"w": jax.random.normal(k, (5,))}, pcfg)
    co = walk_colocation(seed, M, T)
    return pop, co, batch_fn, train_fn, pcfg

def assert_bitwise(a, b, what):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), what
"""


@pytest.mark.slow
def test_distributed_scan_matches_per_step_loop_multidevice(
        multi_device_runner):
    """Scan-vs-per-step bitwise parity on a real (2, 4) mesh, both
    freshness statistics, both training modes."""
    multi_device_runner(_SCAN_PRELUDE + """
for mode in ("fixed", "mobile"):
    for stat in ("median", "meanstd"):
        pop, co, batch_fn, train_fn, pcfg = linear_setup(mode, stat=stat)
        dcfg = DistributedConfig(pop=pcfg)
        dstate = to_distributed_state(pop, dcfg)
        key = jax.random.PRNGKey(3)
        f1, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                             dcfg, mesh, key)
        f2, last2 = run_population_distributed_loop(
            dstate, co, batch_fn, train_fn, dcfg, mesh, key)
        assert_bitwise(f1, f2, (mode, stat))
        assert np.array_equal(np.asarray(aux["last_fid"]), np.asarray(last2))
print("OK")
""")


@pytest.mark.slow
def test_distributed_scan_matches_single_host_multidevice(
        multi_device_runner):
    """Accept-all filter: the mule-sharded scan agrees with the single-host
    engine on all state, both modes (mobile relies on the global-split
    key discipline)."""
    multi_device_runner(_SCAN_PRELUDE + """
for mode in ("fixed", "mobile"):
    pop, co, batch_fn, train_fn, pcfg = linear_setup(
        mode, init_threshold=1e9, warmup=10**6)
    dcfg = DistributedConfig(pop=pcfg)
    key = jax.random.PRNGKey(5)
    host, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    dist, _ = run_population_distributed(to_distributed_state(pop, dcfg),
                                         co, batch_fn, train_fn, dcfg,
                                         mesh, key)
    for k in ("fixed_models", "mule_models", "mule_ts"):
        for a, b in zip(jax.tree.leaves(host[k]), jax.tree.leaves(dist[k])):
            err = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            assert err < 1e-5, (mode, k, err)
print("OK")
""")


@pytest.mark.slow
def test_distributed_sweep_bitwise_multidevice(multi_device_runner):
    """Lane i of a vmapped distributed sweep == the i-th sequential
    distributed run on the same mesh (seed axis outside the mule axis)."""
    multi_device_runner(_SCAN_PRELUDE + """
seeds = [0, 1, 2]
setups = [linear_setup("fixed", seed=s) for s in seeds]
_, _, batch_fn, train_fn, pcfg = setups[0]
dcfg = DistributedConfig(pop=pcfg)
keys = [jax.random.PRNGKey(100 + s) for s in seeds]
finals = [run_population_distributed(
    to_distributed_state(st, dcfg), co, batch_fn, train_fn, dcfg, mesh,
    k)[0] for (st, co, _, _, _), k in zip(setups, keys)]
states = stack_trees([to_distributed_state(s[0], dcfg) for s in setups])
cos = stack_colocations([s[1] for s in setups])
vf, aux = run_sweep_distributed(states, cos, batch_fn, train_fn, dcfg,
                                mesh, stack_trees(keys))
for i in range(len(seeds)):
    assert_bitwise(jax.tree.map(lambda l: l[i], vf), finals[i], i)
assert aux["last_fid"].shape == (len(seeds), M)
print("OK")
""")


@pytest.mark.slow
def test_peer_baselines_sharded_multidevice(multi_device_runner):
    """Ring-ppermute peer baselines on a real (2, 4) pod x data mesh:
    scan == per-step driver bitwise for gossip/oppcl/mlmule+gossip, and
    vs single host — oppcl bitwise (its peer pick is a lexicographic min,
    independent of ring order, and all its float math is row-local),
    gossip/hybrid to tolerance (ring/psum accumulation order)."""
    multi_device_runner(_SCAN_PRELUDE + """
from repro.mobility import markov_churn_mask
for method in ("gossip", "oppcl", "mlmule+gossip"):
    pop, co, batch_fn, train_fn, pcfg = linear_setup(
        "mobile", init_threshold=1e9, warmup=10**6)
    co = dict(co)
    co["active"] = markov_churn_mask(77, T, M, p_leave=0.2, p_join=0.3)
    assert co["active"].any() and not co["active"].all()
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    key = jax.random.PRNGKey(7)
    f1, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                         dcfg, mesh, key, method=method)
    f2, last2 = run_population_distributed_loop(
        dstate, co, batch_fn, train_fn, dcfg, mesh, key, method=method)
    assert_bitwise(f1["mule_models"], f2["mule_models"],
                   ("scan-vs-loop", method))
    assert np.array_equal(np.asarray(aux["last_fid"]), np.asarray(last2))
    host, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                             method=method)
    if method == "oppcl":
        assert_bitwise(host["mule_models"], f1["mule_models"],
                       "oppcl host-vs-dist")
    else:
        for a, b in zip(jax.tree.leaves(host["mule_models"]),
                        jax.tree.leaves(f1["mule_models"])):
            err = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            assert err < 1e-5, ("host-vs-dist", method, err)
print("OK")
""")


@pytest.mark.slow
def test_migrate_mules_round_trip_bitwise(multi_device_runner):
    """n_pods applications of migrate_mules walk every flagged slot around
    the whole pod ring back to its origin — leaves round-trip bitwise."""
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import migrate_mules
mesh = jax.make_mesh((2, 2), ("pod", "data"))
M = 8
models = {"w": jax.random.normal(jax.random.PRNGKey(0), (M, 3)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (M,))}
models = jax.device_put(models, NamedSharding(mesh, P("data")))
mask = jnp.array([True, False, True, False, False, True, False, False])
out = models
for _ in range(mesh.shape["pod"]):
    out = migrate_mules(out, mask, mesh)
for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(models)):
    assert np.array_equal(np.asarray(a), np.asarray(b)), "round trip diverged"
print("OK")
""", n_devices=4)


@pytest.mark.slow
def test_distributed_engine_matches_reference(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.population import PopulationConfig, init_population, population_step
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.core.freshness import FreshnessConfig
from repro.scenarios import run_population_distributed_loop

mesh = jax.make_mesh((2, 4), ("pod", "data"))
F, M = 8, 16
def init_model(k): return {"w": jax.random.normal(k, (4, 3))}
def train_fn(params, batch, key): return jax.tree.map(lambda p: p - 0.01, params)
pcfg = PopulationConfig(mode="fixed", n_fixed=F, n_mules=M, gamma=0.5,
                        freshness=FreshnessConfig(init_threshold=1e9, warmup=10**6))
state = init_population(jax.random.PRNGKey(0), init_model, pcfg)
fid = jnp.array([0,1,2,3,4,5,6,7,0,1,-1,3,4,-1,6,7], jnp.int32)
exch = jnp.array([True]*10 + [False]*2 + [True]*4)
info = {"fixed_id": fid, "exchange": exch}
fixed_batches = jnp.zeros((F, 2))
key = jax.random.PRNGKey(7)
ref = population_step(dict(state), info, {"fixed": fixed_batches, "mule": None},
                      train_fn, pcfg, key)
dcfg = DistributedConfig(pop=pcfg)
co = {"fixed_id": np.asarray(fid)[None], "exchange": np.asarray(exch)[None]}
final, _ = run_population_distributed_loop(
    to_distributed_state(state, dcfg), co,
    {"fixed": fixed_batches[None], "mule": None}, train_fn, dcfg, mesh, key)
err_f = max(float(jnp.max(jnp.abs(a-b))) for a,b in
            zip(jax.tree.leaves(final["fixed_models"]), jax.tree.leaves(ref["fixed_models"])))
err_m = max(float(jnp.max(jnp.abs(a-b))) for a,b in
            zip(jax.tree.leaves(final["mule_models"]), jax.tree.leaves(ref["mule_models"])))
assert err_f < 1e-6 and err_m < 1e-6, (err_f, err_m)
print("OK")
""")


@pytest.mark.slow
def test_migrate_mules_swaps_pods(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import migrate_mules
mesh = jax.make_mesh((2, 2), ("pod", "data"))
M = 8
models = {"w": jnp.arange(M, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))}
models = jax.device_put(models, NamedSharding(mesh, P("data")))
mask = jnp.array([True] + [False]*(M-1))
with mesh:
    out = migrate_mules(models, mask, mesh)
w = np.asarray(out["w"])
# mule slot 0 on each pod swapped with the other pod's slot 0... but with
# population sharded over data only, each pod holds a full replica and
# ppermute swaps replicas; flagged slot keeps shape and stays finite.
assert w.shape == (M, 3) and np.isfinite(w).all()
print("OK")
""")


@pytest.mark.slow
def test_sharded_moe_matches_local(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_smoke_config
from repro.models.moe import init_moe, apply_moe
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                          dtype="float32", capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model)) * 0.5
out_ref, _ = apply_moe(params, x, cfg)
with mesh:
    out_sh, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg, mesh=mesh))(params, x)
    g_sh = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg, mesh=mesh)[0].sum()))(params, x)
g_ref = jax.grad(lambda p, x: apply_moe(p, x, cfg)[0].sum())(params, x)
err = float(jnp.max(jnp.abs(out_ref - out_sh)))
gerr = max(float(jnp.max(jnp.abs(a-b))) for a, b in
           zip(jax.tree.leaves(g_sh), jax.tree.leaves(g_ref)))
assert err < 1e-5 and gerr < 1e-5, (err, gerr)
print("OK")
""")


@pytest.mark.slow
def test_smoke_mesh_train_step(multi_device_runner):
    """A reduced arch trains one step under a (2,2) mesh with the production
    sharding rules — CI-scale version of the dry-run."""
    multi_device_runner("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.sharding import batch_specs, param_specs, to_named
from repro.launch.steps import make_train_step
from repro.optim import sgd
from repro.configs import InputShape
mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = get_smoke_config("stablelm-1.6b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = sgd(0.01)
opt_state = opt.init(params)
step = make_train_step(model, opt)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)}
pspecs = param_specs(cfg, params, mesh)
shape = InputShape("t", 32, 4, "train")
bspecs = batch_specs(cfg, shape, mesh)
with mesh:
    fn = jax.jit(step, in_shardings=(to_named(pspecs, mesh), None,
                                     to_named(bspecs, mesh)))
    p2, o2, metrics = fn(params, opt_state, batch)
assert bool(jnp.isfinite(metrics["loss"]))
print("OK", float(metrics["loss"]))
""", n_devices=4)


@pytest.mark.slow
def test_distributed_churn_parity_multidevice(multi_device_runner):
    """Churn on a real (2, 4) pod x data mesh: the masked shard_map scan
    matches the masked per-step driver bitwise, and (accept-all filter)
    matches the single-host masked engine — inactive mules drop out of the
    fused psum payload identically on every shard."""
    multi_device_runner(_SCAN_PRELUDE + """
from repro.mobility import markov_churn_mask
for mode in ("fixed", "mobile"):
    pop, co, batch_fn, train_fn, pcfg = linear_setup(
        mode, init_threshold=1e9, warmup=10**6)
    co = dict(co)
    co["active"] = markov_churn_mask(77, T, M, p_leave=0.2, p_join=0.3)
    assert co["active"].any() and not co["active"].all()
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    key = jax.random.PRNGKey(7)
    f1, aux = run_population_distributed(dstate, co, batch_fn, train_fn,
                                         dcfg, mesh, key)
    f2, last2 = run_population_distributed_loop(
        dstate, co, batch_fn, train_fn, dcfg, mesh, key)
    assert_bitwise(f1, f2, ("scan-vs-loop", mode))
    assert np.array_equal(np.asarray(aux["last_fid"]), np.asarray(last2))
    host, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    for k in ("fixed_models", "mule_models", "mule_ts"):
        # across real shards the psum's reduction order differs from the
        # single-host matmul, so host agreement is to tolerance (the
        # bitwise host-vs-dist pin lives in the 1-device fast tier)
        for a, b in zip(jax.tree.leaves(host[k]), jax.tree.leaves(f1[k])):
            err = float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            assert err < 1e-5, ("host-vs-dist", mode, k, err)
print("OK")
""")
