"""Distributed engine + sharded MoE: multi-device subprocess tests."""
import pytest


@pytest.mark.slow
def test_distributed_engine_matches_reference(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp
from repro.core.population import PopulationConfig, init_population, population_step
from repro.core.distributed import DistributedConfig, make_distributed_step
from repro.core.freshness import FreshnessConfig

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
F, M = 8, 16
def init_model(k): return {"w": jax.random.normal(k, (4, 3))}
def train_fn(params, batch, key): return jax.tree.map(lambda p: p - 0.01, params)
pcfg = PopulationConfig(mode="fixed", n_fixed=F, n_mules=M, gamma=0.5,
                        freshness=FreshnessConfig(init_threshold=1e9, warmup=10**6))
state = init_population(jax.random.PRNGKey(0), init_model, pcfg)
fid = jnp.array([0,1,2,3,4,5,6,7,0,1,-1,3,4,-1,6,7], jnp.int32)
exch = jnp.array([True]*10 + [False]*2 + [True]*4)
info = {"fixed_id": fid, "exchange": exch}
fixed_batches = jnp.zeros((F, 2))
key = jax.random.PRNGKey(7)
ref = population_step(dict(state), info, {"fixed": fixed_batches, "mule": None},
                      train_fn, pcfg, key)
step = make_distributed_step(train_fn, DistributedConfig(pop=pcfg), mesh)
thr = jnp.full((F,), 1e9, jnp.float32)
with mesh:
    mm, mts, fm, nthr, t = step(state["mule_models"], state["mule_ts"],
                                state["fixed_models"], thr, state["t"],
                                fid, exch, fixed_batches, jnp.zeros((M,2)), key)
err_f = max(float(jnp.max(jnp.abs(a-b))) for a,b in
            zip(jax.tree.leaves(fm), jax.tree.leaves(ref["fixed_models"])))
err_m = max(float(jnp.max(jnp.abs(a-b))) for a,b in
            zip(jax.tree.leaves(mm), jax.tree.leaves(ref["mule_models"])))
assert err_f < 1e-6 and err_m < 1e-6, (err_f, err_m)
print("OK")
""")


@pytest.mark.slow
def test_migrate_mules_swaps_pods(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.distributed import migrate_mules
mesh = jax.make_mesh((2, 2), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
M = 8
models = {"w": jnp.arange(M, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))}
models = jax.device_put(models, NamedSharding(mesh, P("data")))
mask = jnp.array([True] + [False]*(M-1))
with mesh:
    out = migrate_mules(models, mask, mesh)
w = np.asarray(out["w"])
# mule slot 0 on each pod swapped with the other pod's slot 0... but with
# population sharded over data only, each pod holds a full replica and
# ppermute swaps replicas; flagged slot keeps shape and stays finite.
assert w.shape == (M, 3) and np.isfinite(w).all()
print("OK")
""")


@pytest.mark.slow
def test_sharded_moe_matches_local(multi_device_runner):
    multi_device_runner("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_smoke_config
from repro.models.moe import init_moe, apply_moe
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                          dtype="float32", capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model)) * 0.5
out_ref, _ = apply_moe(params, x, cfg)
with mesh:
    out_sh, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg, mesh=mesh))(params, x)
    g_sh = jax.jit(jax.grad(lambda p, x: apply_moe(p, x, cfg, mesh=mesh)[0].sum()))(params, x)
g_ref = jax.grad(lambda p, x: apply_moe(p, x, cfg)[0].sum())(params, x)
err = float(jnp.max(jnp.abs(out_ref - out_sh)))
gerr = max(float(jnp.max(jnp.abs(a-b))) for a, b in
           zip(jax.tree.leaves(g_sh), jax.tree.leaves(g_ref)))
assert err < 1e-5 and gerr < 1e-5, (err, gerr)
print("OK")
""")


@pytest.mark.slow
def test_smoke_mesh_train_step(multi_device_runner):
    """A reduced arch trains one step under a (2,2) mesh with the production
    sharding rules — CI-scale version of the dry-run."""
    multi_device_runner("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.launch.sharding import batch_specs, param_specs, to_named
from repro.launch.steps import make_train_step
from repro.optim import sgd
from repro.configs import InputShape
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = get_smoke_config("stablelm-1.6b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = sgd(0.01)
opt_state = opt.init(params)
step = make_train_step(model, opt)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)}
pspecs = param_specs(cfg, params, mesh)
shape = InputShape("t", 32, 4, "train")
bspecs = batch_specs(cfg, shape, mesh)
with mesh:
    fn = jax.jit(step, in_shardings=(to_named(pspecs, mesh), None,
                                     to_named(bspecs, mesh)))
    p2, o2, metrics = fn(params, opt_state, batch)
assert bool(jnp.isfinite(metrics["loss"]))
print("OK", float(metrics["loss"]))
""", n_devices=4)
