"""Streaming colocation engine: generators, parity, and the T-free cache.

The streamed replay's whole contract is "indistinguishable from the
materialized engine, minus the [T, M] memory" — so nearly every test here
is a bitwise pin: on-device ``generate_chunk`` against the host tensors
for every registered scenario (chunk boundaries included),
``run_population_streamed`` against ``run_population`` for every method,
evals included, single-host against distributed, and the procedural
commuter stream against an independent host re-derivation of its dwell
cadence. The cache tests pin the perf claim: the compiled chunk program
must not depend on the horizon.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.mobility import (CommuterStream, commuter_stream,
                            compact_colocation, dwell_exchange_flags,
                            materialize_generator)
from repro.scenarios import (get_scenario, list_scenarios, run_population,
                             run_population_streamed, scenario_generator)
from repro.scenarios.engine import (_colocation_tensors, jit_cache_clear,
                                    jit_cache_stats)

from conftest import assert_trees_bitwise, linear_population_setup

M, T = 6, 30


def _expand_chunked(gen, n_steps, chunk_len):
    """Concatenate generate_chunk over an awkwardly-chunked horizon."""
    outs = []
    for t0 in range(0, n_steps, chunk_len):
        outs.append(gen.generate_chunk(None, t0,
                                       min(chunk_len, n_steps - t0)))
    area0 = np.asarray(outs[0]["area"])
    return {
        "fixed_id": np.concatenate(
            [np.asarray(o["fixed_id"]) for o in outs], 0),
        "exchange": np.concatenate(
            [np.asarray(o["exchange"]) for o in outs], 0),
        "pos": np.concatenate([np.asarray(o["pos"]) for o in outs], 0),
        "active": np.concatenate([np.asarray(o["active"]) for o in outs], 0),
        "area": (np.concatenate([np.asarray(o["area"]) for o in outs], 0)
                 if area0.ndim == 2 else area0),
    }


# ---------------------------------------------------------------------------
# generator <-> host-tensor parity over the whole registry
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_mules=st.integers(min_value=2, max_value=14),
       n_steps=st.integers(min_value=50, max_value=180),
       chunk_len=st.integers(min_value=7, max_value=48))
def test_every_scenario_streams_bitwise(seed, n_mules, n_steps, chunk_len):
    """On-device generate_chunk == host colocation tensors, bitwise, for
    every registered scenario — at chunk lengths that do NOT divide the
    horizon, so run boundaries straddle chunk boundaries."""
    for name in list_scenarios():
        spec = get_scenario(name)
        co = spec.colocation(seed, n_mules, n_steps)
        gen = scenario_generator(spec, seed, n_mules, n_steps,
                                 colocation=co)
        fid, exch, pos, area, act = _colocation_tensors(co)
        got = _expand_chunked(gen, n_steps, chunk_len)
        for key, ref in (("fixed_id", fid), ("exchange", exch),
                         ("pos", pos), ("active", act), ("area", area)):
            assert np.array_equal(got[key], np.asarray(ref)), \
                f"{name}: streamed {key} != host tensors"


def test_scenario_generator_reuses_prebuilt_colocation():
    """Passing colocation= skips the rebuild but yields the same stream."""
    spec = get_scenario("commuter")
    co = spec.colocation(1, M, T)
    a = scenario_generator(spec, 1, M, T, colocation=co)
    b = scenario_generator("commuter", 1, M, T)
    assert_trees_bitwise(a.generate_chunk(None, 11, 9),
                         b.generate_chunk(None, 11, 9))


def test_compact_falls_back_to_exchange_rle_when_cadence_lies():
    """A schedule whose exchange is NOT dwell-cadence-shaped still streams
    bitwise — compaction detects the mismatch and RLE-encodes exchange."""
    co = get_scenario("commuter").colocation(0, M, 90)
    weird = dict(co)
    rng = np.random.RandomState(0)
    weird["exchange"] = (co["fixed_id"] >= 0) & (rng.rand(90, M) < 0.3)
    gen = compact_colocation(weird)
    assert gen._has_exchange_rle
    got = _expand_chunked(gen, 90, 28)
    assert np.array_equal(got["exchange"], weird["exchange"])
    assert np.array_equal(got["fixed_id"], np.asarray(co["fixed_id"]))


# ---------------------------------------------------------------------------
# the procedural commuter stream
# ---------------------------------------------------------------------------


def test_commuter_stream_exchange_matches_dwell_cadence():
    """Independent host check: materializing the procedural generator and
    re-deriving exchange from dwell runs reproduces its on-device flags —
    i.e. the closed-form run-start math (cross-midnight continuation
    included) agrees with the host dwell counter."""
    gen = commuter_stream(0, 16, 700)
    co = materialize_generator(gen, chunk_len=97)
    assert np.array_equal(
        dwell_exchange_flags(co["fixed_id"], gen.exchange_steps),
        co["exchange"])


def test_commuter_stream_compaction_roundtrip():
    """compact(materialize(gen)) expands exactly like gen itself, and uses
    the closed-form cadence (no RLE fallback) — the generator's exchange
    semantics are the engine's dwell semantics."""
    gen = commuter_stream(3, 10, 400)
    cg = compact_colocation(materialize_generator(gen), cadence=3)
    assert not cg._has_exchange_rle
    assert_trees_bitwise(gen.generate_chunk(None, 123, 50),
                         cg.generate_chunk(None, 123, 50))


def test_commuter_stream_is_registered_and_valid():
    spec = get_scenario("streaming_commuter")
    assert spec.generator is not None
    co = spec.colocation(0, 8, 120)
    fid = np.asarray(co["fixed_id"])
    assert fid.shape == (120, 8) and fid.min() >= -1 \
        and fid.max() < spec.n_fixed
    assert "init_space" in co and "init_area" in co


def test_commuter_stream_duty_cycle_churn_keeps_liveness():
    gen = commuter_stream(0, 9, 300, duty_period=40)
    co = materialize_generator(gen)
    act = np.asarray(co["active"])
    assert act.shape == (300, 9) and not act.all()
    assert act.any(axis=1).all(), "step with zero active mules"


def test_commuter_stream_memory_is_horizon_free():
    short = commuter_stream(0, 32, 100)
    long = commuter_stream(0, 32, 10 ** 7)
    assert short.schedule_bytes() == long.schedule_bytes()
    assert_trees_bitwise(short.arrays(), long.arrays())


# ---------------------------------------------------------------------------
# streamed replay == materialized replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method",
                         ["mlmule", "gossip", "oppcl", "local",
                          "mlmule+gossip"])
def test_streamed_replay_matches_materialized(method):
    """run_population_streamed == run_population, bitwise, per method —
    with a chunk length that does not divide the horizon."""
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    key = jax.random.PRNGKey(7)
    gen = compact_colocation(co)
    ref, aux_ref = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                  method=method)
    st, aux = run_population_streamed(pop, gen, batch_fn, train_fn, pcfg,
                                      key, n_steps=T, chunk_len=8,
                                      method=method, donate=False)
    assert_trees_bitwise(ref, st, f"{method}: streamed state diverged")
    assert_trees_bitwise(aux_ref["last_fid"], aux["last_fid"])


def test_streamed_evals_match_materialized():
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    key = jax.random.PRNGKey(7)

    def eval_fn(state, last):
        return {"wmean": jax.tree.map(lambda l: l.mean(),
                                      state["mule_models"]),
                "lmax": last.max()}

    ref, aux_ref = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                  eval_every=5, eval_fn=eval_fn)
    st, aux = run_population_streamed(pop, compact_colocation(co), batch_fn,
                                      train_fn, pcfg, key, n_steps=T,
                                      chunk_len=10, eval_every=5,
                                      eval_fn=eval_fn, donate=False)
    assert_trees_bitwise(ref, st)
    assert_trees_bitwise(aux_ref["evals"], aux["evals"])
    np.testing.assert_array_equal(aux_ref["eval_steps"], aux["eval_steps"])


def test_streamed_rejects_misaligned_eval_chunks():
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    with pytest.raises(ValueError, match="multiple of"):
        run_population_streamed(pop, compact_colocation(co), batch_fn,
                                train_fn, pcfg, jax.random.PRNGKey(0),
                                n_steps=T, chunk_len=8, eval_every=5,
                                eval_fn=lambda s, l: l.max(), donate=False)


def test_streamed_stacked_batches_match():
    """Stacked [T, ...] batch pytrees slice per chunk like the scan does."""
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, T)
    stacked = jax.vmap(lambda k: batch_fn(k, 0))(ks)
    ref, _ = run_population(pop, co, stacked, train_fn, pcfg, key)
    st, _ = run_population_streamed(pop, compact_colocation(co), stacked,
                                    train_fn, pcfg, key, n_steps=T,
                                    chunk_len=8, donate=False)
    assert_trees_bitwise(ref, st, "stacked-batch streamed run diverged")


def test_streamed_registered_scenario_end_to_end():
    """streaming_commuter: native generator vs its materialized builder."""
    spec = get_scenario("streaming_commuter")
    pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    co = spec.colocation(0, M, T)
    gen = scenario_generator(spec, 0, M, T)
    assert isinstance(gen, CommuterStream)
    key = jax.random.PRNGKey(11)
    ref, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    st, _ = run_population_streamed(pop, gen, batch_fn, train_fn, pcfg, key,
                                    chunk_len=7, donate=False)
    assert_trees_bitwise(ref, st, "streaming_commuter diverged")


# ---------------------------------------------------------------------------
# the horizon-free jit cache + donation
# ---------------------------------------------------------------------------


def test_chunk_cache_is_horizon_free():
    """Replays of different lengths (and fresh same-shape generators) hit
    one compiled chunk program: zero new traces."""
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    key = jax.random.PRNGKey(1)
    jit_cache_clear()
    run_population_streamed(pop, compact_colocation(co), batch_fn, train_fn,
                            pcfg, key, n_steps=24, chunk_len=8,
                            donate=False)
    t1 = jit_cache_stats()["traces"]
    assert t1 == 1, "full-size chunks should share one trace"
    run_population_streamed(pop, compact_colocation(co), batch_fn, train_fn,
                            pcfg, key, n_steps=16, chunk_len=8,
                            donate=False)
    assert jit_cache_stats()["traces"] == t1, \
        "a new horizon retraced the chunk program"


def test_streamed_donation_runs_in_place():
    """donate=True (the default) frees the carry each chunk; results match
    an undonated run."""
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    key = jax.random.PRNGKey(2)
    gen = compact_colocation(co)
    ref, _ = run_population_streamed(pop, gen, batch_fn, train_fn, pcfg,
                                     key, n_steps=T, chunk_len=8,
                                     donate=False)
    donor = jax.tree.map(jnp.copy, pop)
    st, _ = run_population_streamed(donor, gen, batch_fn, train_fn, pcfg,
                                    key, n_steps=T, chunk_len=8)
    assert_trees_bitwise(ref, st, "donated streamed run diverged")


# ---------------------------------------------------------------------------
# distributed streaming (1-device mesh: shard_map is exact in tier-1)
# ---------------------------------------------------------------------------


def _mesh():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))


@pytest.mark.parametrize("method", ["mlmule", "gossip", "oppcl"])
def test_distributed_streamed_matches_single_host(method):
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    key = jax.random.PRNGKey(3)
    gen = compact_colocation(co)
    ref, aux_ref = run_population_streamed(pop, gen, batch_fn, train_fn,
                                           pcfg, key, n_steps=T,
                                           chunk_len=8, method=method,
                                           donate=False)
    st, aux = run_population_streamed(dstate, gen, batch_fn, train_fn,
                                      pcfg, key, n_steps=T, chunk_len=8,
                                      method=method, donate=False,
                                      mesh=_mesh(), dcfg=dcfg)
    assert_trees_bitwise({k: ref[k] for k in ("mule_models", "mule_ts")
                          if k in ref},
                         {k: st[k] for k in ("mule_models", "mule_ts")
                          if k in st},
                         f"{method}: distributed streamed diverged")
    assert_trees_bitwise(aux_ref["last_fid"], aux["last_fid"])


def test_distributed_streamed_requires_dcfg():
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    with pytest.raises(ValueError, match="mesh requires dcfg"):
        run_population_streamed(pop, compact_colocation(co), batch_fn,
                                train_fn, pcfg, jax.random.PRNGKey(0),
                                n_steps=T, mesh=_mesh())


@pytest.mark.slow
def test_distributed_streamed_multi_device_shards_generator():
    """On a real multi-device mesh each shard expands only its own mule
    columns; the result still matches single-host bitwise (mlmule's psum
    schedule is shard-count invariant)."""
    from conftest import run_with_devices
    code = """
import jax, numpy as np
import jax.numpy as jnp
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.mobility import compact_colocation
from repro.scenarios import run_population_streamed
import sys, os
sys.path.insert(0, os.path.join(os.getcwd(), "tests"))
from conftest import linear_population_setup, assert_trees_bitwise

M, T = 8, 30
pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
    n_mules=M, n_steps=T)
dcfg = DistributedConfig(pop=pcfg)
dstate = to_distributed_state(pop, dcfg)
key = jax.random.PRNGKey(5)
gen = compact_colocation(co)
mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:4]).reshape(1, 4), ("pod", "data"))
ref, _ = run_population_streamed(pop, gen, batch_fn, train_fn, pcfg, key,
                                 n_steps=T, chunk_len=8, donate=False)
st, _ = run_population_streamed(dstate, gen, batch_fn, train_fn, pcfg, key,
                                n_steps=T, chunk_len=8, donate=False,
                                mesh=mesh, dcfg=dcfg)
assert_trees_bitwise(ref["mule_models"], st["mule_models"])
print("MULTIDEV_STREAM_OK")
"""
    assert "MULTIDEV_STREAM_OK" in run_with_devices(code, n_devices=4)


# ---------------------------------------------------------------------------
# _colocation_tensors: device arrays pass through without a host round-trip
# ---------------------------------------------------------------------------


def test_colocation_tensors_keep_device_arrays():
    """A device-resident colocation dict is not copied through the host:
    right-dtype arrays come back as the same object."""
    co = get_scenario("commuter").colocation(0, M, T)
    dev = {
        "fixed_id": jnp.asarray(co["fixed_id"], jnp.int32),
        "exchange": jnp.asarray(co["exchange"], bool),
        "pos": jnp.asarray(co["pos"], jnp.float32),
        "area": jnp.asarray(co["area"], jnp.int32),
    }
    fid, exch, pos, area, act = _colocation_tensors(dev)
    assert fid is dev["fixed_id"]
    assert exch is dev["exchange"]
    assert pos is dev["pos"]
    assert area is dev["area"]
    # host inputs still upload + normalize like before
    fid2, *_ = _colocation_tensors(co)
    assert np.array_equal(np.asarray(fid), np.asarray(fid2))


def test_colocation_tensors_cast_wrong_dtype_on_device():
    co = get_scenario("commuter").colocation(0, M, T)
    dev = {"fixed_id": jnp.asarray(co["fixed_id"], jnp.int64)
           if jax.config.jax_enable_x64 else
           jnp.asarray(co["fixed_id"], jnp.int16),
           "exchange": jnp.asarray(co["exchange"])}
    fid, exch, *_ = _colocation_tensors(dev)
    assert fid.dtype == jnp.int32
    assert np.array_equal(np.asarray(fid), co["fixed_id"])
