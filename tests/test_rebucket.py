"""Mid-run re-bucketing: drift-triggered mule swaps on long mobile traces.

Build-time bucketing (`bucket_mule_order` at colocation build) decays as
mules migrate between areas; these tests pin the machinery that keeps the
ring's hop pruning effective mid-run — the permutation primitives round-trip
over the full state/colocation/generator surface, the streamed driver's
drift check fires and swaps without perturbing results (pruned == full ring
across a swap; static-area runs are bitwise-identical with re-bucketing on
or off), the distributed engine delegates, the config lands in the jit
cache key, and the auto-width area bitmask stops aliasing past 32 areas.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.gossip import (area_bit_collision_rate, area_bits,
                                    ring_hop_mask)
from repro.core.distributed import (DistributedConfig, bucket_locality_fraction,
                                    bucket_mule_order, reorder_colocation,
                                    reorder_mule_state, to_distributed_state)
from repro.mobility import (area_over_time, compact_colocation,
                            reorder_generator_arrays)
from repro.scenarios import (get_scenario, list_scenarios,
                             run_population_distributed,
                             run_population_streamed)
from repro.scenarios.engine import (_resolve_ring_bits, jit_cache_clear,
                                    jit_cache_stats)

from conftest import assert_trees_bitwise, linear_population_setup

M, T = 8, 96


def _migratory(seed=0, m=M, t=T):
    return get_scenario("multi_area_migratory").colocation(seed, m, t)


def _mesh():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))


# ---------------------------------------------------------------------------
# permutation primitives
# ---------------------------------------------------------------------------


def test_bucket_locality_fraction_counts_ragged_tail():
    """M=7 over 4 shards: np.array_split blocks are [2, 2, 2, 1]; the pairs
    of the ragged tail count (the old equal-block slice dropped mule 6,
    silently inflating locality)."""
    area = np.array([0, 0, 0, 0, 1, 1, 1])
    # same-area ordered pairs: area 0 -> 4*3 = 12, area 1 -> 3*2 = 6.
    # blocks [0,0] [0,0] [1,1] [1]: local pairs 2 + 2 + 2 = 6 of 18.
    got = bucket_locality_fraction(area, 4)
    assert got == pytest.approx(6 / 18)
    # all-distinct areas: no candidate pairs at all -> 1.0 by convention
    assert bucket_locality_fraction(np.arange(7), 4) == 1.0


@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_reorder_colocation_roundtrips_every_scenario(name):
    co = get_scenario(name).colocation(0, M, 48)
    rng = np.random.default_rng(3)
    order = rng.permutation(M)
    inv = np.argsort(order)
    fwd = reorder_colocation(co, order)
    np.testing.assert_array_equal(np.asarray(fwd["fixed_id"]),
                                  np.asarray(co["fixed_id"])[:, order])
    back = reorder_colocation(fwd, inv)
    for k in co:
        np.testing.assert_array_equal(
            np.asarray(back[k]), np.asarray(co[k]), err_msg=f"{name}:{k}")


def test_reorder_mule_state_roundtrips_and_spares_replicated():
    rng = np.random.default_rng(0)
    state = {
        "mule_models": {"w": jnp.asarray(rng.normal(size=(M, 5)))},
        "mule_ts": jnp.arange(M),
        "fixed_models": {"w": jnp.asarray(rng.normal(size=(4, 5)))},
        "sketch": jnp.asarray(rng.normal(size=(7,))),
        "mule_opt": None,
    }
    order = rng.permutation(M)
    fwd = reorder_mule_state(state, order)
    np.testing.assert_array_equal(np.asarray(fwd["mule_ts"]),
                                  np.arange(M)[order])
    assert fwd["fixed_models"]["w"] is state["fixed_models"]["w"]
    assert fwd["mule_opt"] is None
    back = reorder_mule_state(fwd, np.argsort(order))
    assert_trees_bitwise(
        {k: v for k, v in back.items() if v is not None},
        {k: v for k, v in state.items() if v is not None},
        "reorder_mule_state round-trip")


@pytest.mark.parametrize("name", ["multi_area_migratory", "commuter_churn"])
def test_reorder_generator_arrays_matches_rebuilt_generator(name):
    """Permuting a generator's in-flight mule columns equals compacting the
    permuted colocation from scratch (RLE is per-mule, so rows follow their
    mules), and the inverse permutation restores the original arrays."""
    co = get_scenario(name).colocation(0, M, 64)
    gen = compact_colocation(co)
    order = np.random.default_rng(1).permutation(M)
    fwd = reorder_generator_arrays(gen, gen.arrays(), order)
    rebuilt = compact_colocation(reorder_colocation(co, order)).arrays()
    assert sorted(fwd) == sorted(rebuilt)
    for k in fwd:
        np.testing.assert_array_equal(np.asarray(fwd[k]),
                                      np.asarray(rebuilt[k]), err_msg=k)
    back = reorder_generator_arrays(gen, fwd, np.argsort(order))
    for k in back:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(gen.arrays()[k]), err_msg=k)


def test_area_over_time_holds_last_known_area():
    fid = np.array([[-1, 4], [8, -1], [-1, -1], [0, 5]], np.int32)
    init = np.array([3, 1])
    got = area_over_time(fid, init, places_per_area=4)
    want = np.array([[3, 1], [2, 1], [2, 1], [0, 1]], np.int32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# streamed driver: drift check, swaps, parity
# ---------------------------------------------------------------------------


def _streamed(co, *, rebucket_every=0, threshold=0.25, ring_prune=True,
              chunk_len=16, seed=0):
    pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T, seed=seed)
    dcfg = DistributedConfig(pop=pcfg, ring_prune=ring_prune,
                             rebucket_every=rebucket_every,
                             rebucket_threshold=threshold)
    dstate = to_distributed_state(pop, dcfg)
    return run_population_streamed(
        dstate, compact_colocation(co), batch_fn, train_fn, pcfg,
        jax.random.PRNGKey(7), n_steps=T, chunk_len=chunk_len,
        method="oppcl", donate=False, mesh=_mesh(), dcfg=dcfg)


def test_rebucket_rejects_misaligned_chunks():
    pop, co, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    dcfg = DistributedConfig(pop=pcfg, rebucket_every=24)
    with pytest.raises(ValueError, match="rebucket_every=24.*chunk_len=16"):
        run_population_streamed(
            to_distributed_state(pop, dcfg), compact_colocation(co),
            batch_fn, train_fn, pcfg, jax.random.PRNGKey(0), n_steps=T,
            chunk_len=16, donate=False, mesh=_mesh(), dcfg=dcfg)


def test_rebucket_on_static_area_is_bitwise_identity():
    """With a static [M] area the drift scalar is 0 at every check: no
    swaps fire and the run is bitwise-identical to re-bucketing off."""
    co = get_scenario("multi_area_3city").colocation(0, M, T)
    off, _ = _streamed(co)
    on, aux = _streamed(co, rebucket_every=16)
    assert aux["rebucket"]["checks"] == T // 16 - 1
    assert aux["rebucket"]["swaps"] == 0
    np.testing.assert_array_equal(aux["rebucket"]["order"], np.arange(M))
    assert_trees_bitwise(off, on, "static-area rebucket changed results")


def test_rebucket_swaps_fire_and_preserve_ring_parity():
    """The migratory trace drifts past the threshold, so swaps fire — and
    because the swap schedule depends only on the area trace, the pruned
    and full rings stay bitwise-equal across every swap."""
    co = _migratory()
    pruned, aux_p = _streamed(co, rebucket_every=16, threshold=0.1)
    full, aux_f = _streamed(co, rebucket_every=16, threshold=0.1,
                            ring_prune=False)
    assert aux_p["rebucket"]["swaps"] >= 1, \
        f"drift never tripped: {aux_p['rebucket']['drift']}"
    order = aux_p["rebucket"]["order"]
    assert sorted(order.tolist()) == list(range(M))
    np.testing.assert_array_equal(order, aux_f["rebucket"]["order"])
    assert_trees_bitwise(pruned, full, "pruned ring diverged across swap")
    assert_trees_bitwise(aux_p["last_fid"], aux_f["last_fid"])


def test_distributed_engine_delegates_rebucket_to_streamed():
    co = _migratory()
    pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)
    dcfg = DistributedConfig(pop=pcfg, rebucket_every=16,
                             rebucket_threshold=0.1)
    dstate = to_distributed_state(pop, dcfg)
    via_dist, aux_d = run_population_distributed(
        dstate, co, batch_fn, train_fn, dcfg, _mesh(),
        jax.random.PRNGKey(7), method="oppcl", donate=False)
    direct, aux_s = _streamed(co, rebucket_every=16, threshold=0.1)
    assert aux_d["rebucket"]["swaps"] == aux_s["rebucket"]["swaps"]
    np.testing.assert_array_equal(aux_d["rebucket"]["order"],
                                  aux_s["rebucket"]["order"])
    assert_trees_bitwise(via_dist, direct,
                         "distributed delegation diverged from streamed")


def test_rebucket_config_misses_the_jit_cache():
    """DistributedConfig hashes by value into the chunk-program cache key,
    so flipping any rebucket knob must retrace instead of silently reusing
    a program compiled without the drift output (the closures are shared,
    so the config is the only thing that changes between calls)."""
    co = _migratory()
    gen = compact_colocation(co)
    pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
        n_mules=M, n_steps=T)

    def run(threshold):
        dcfg = DistributedConfig(pop=pcfg, rebucket_every=16,
                                 rebucket_threshold=threshold)
        return run_population_streamed(
            to_distributed_state(pop, dcfg), gen, batch_fn, train_fn,
            pcfg, jax.random.PRNGKey(7), n_steps=T, chunk_len=16,
            method="oppcl", donate=False, mesh=_mesh(), dcfg=dcfg)

    jit_cache_clear()
    run(0.1)
    t1 = jit_cache_stats()["traces"]
    run(0.1)                                             # warm: no retrace
    assert jit_cache_stats()["traces"] == t1
    run(0.2)                                             # new threshold
    assert jit_cache_stats()["traces"] > t1


# ---------------------------------------------------------------------------
# area-bitmask width
# ---------------------------------------------------------------------------


def test_ring_bits_auto_width_resolution():
    pcfg = linear_population_setup(n_mules=M, n_steps=8)[4]
    dcfg = DistributedConfig(pop=pcfg)                   # ring_bits=0: auto
    assert _resolve_ring_bits(dcfg, 10).ring_bits == 32
    assert _resolve_ring_bits(dcfg, 40).ring_bits == 64
    pinned = DistributedConfig(pop=pcfg, ring_bits=32)
    assert _resolve_ring_bits(pinned, 40).ring_bits == 32


def test_wide_mask_prunes_what_the_narrow_fold_aliases():
    """Areas 0 and 32 alias under a 32-bit fold (hop kept, never wrongly
    pruned); the 64-bit mask separates them and prunes the hop."""
    area = jnp.concatenate([jnp.zeros(4, jnp.int32),
                            jnp.full(4, 32, jnp.int32)])
    narrow = ring_hop_mask(area, None, 2, n_bits=32)
    wide = ring_hop_mask(area, None, 2, n_bits=64)
    assert bool(narrow[1])                               # aliased: kept
    assert not bool(wide[1])                             # separated: pruned
    assert area_bit_collision_rate(area, n_bits=32) > 0.0
    assert area_bit_collision_rate(area, n_bits=64) == 0.0
    # soundness either way: a genuinely shared area is never pruned
    shared = jnp.concatenate([jnp.arange(4, dtype=jnp.int32),
                              jnp.arange(4, dtype=jnp.int32)])
    assert bool(ring_hop_mask(shared, None, 2, n_bits=32)[1])
    assert bool(ring_hop_mask(shared, None, 2, n_bits=64)[1])
    # 40 distinct areas: the one-hot union sets exactly their bits at 64
    many = jnp.arange(40, dtype=jnp.int32)
    assert int(area_bits(many, n_bits=64).sum()) == 40


# ---------------------------------------------------------------------------
# CLI validation + full-pytree migration round-trip
# ---------------------------------------------------------------------------


def test_cli_rejects_misaligned_rebucket_cadence_up_front():
    """The CLI names both numbers before any device work (the engine would
    only raise after building chunks)."""
    out = subprocess.run(
        [sys.executable, "examples/run_scenario.py", "--distributed",
         "--stream", "--rebucket-every", "100", "--stream-chunk", "64",
         "--scenario", "multi_area_migratory", "--steps", "8",
         "--n-mules", "8"],
        capture_output=True, text=True, timeout=300)
    assert out.returncode != 0
    err = out.stderr
    assert "rebucket-every=100" in err and "stream-chunk=64" in err, err
    # and re-bucketing without a sharded population is refused too
    out = subprocess.run(
        [sys.executable, "examples/run_scenario.py", "--rebucket-every",
         "16", "--scenario", "multi_area_migratory"],
        capture_output=True, text=True, timeout=300)
    assert out.returncode != 0
    assert "--distributed" in out.stderr


@pytest.mark.slow
def test_rebucket_ring_parity_on_real_shards(multi_device_runner):
    """On a 4-shard mesh — where pruning actually skips hops — the pruned
    and full rings stay parity-equal across mid-run swaps: bitwise for
    oppcl, <= 1e-5 for the gossip mix (PR 7's invariant, now under a
    permutation of the live state)."""
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
import dataclasses, sys, os
sys.path.insert(0, os.path.join(os.getcwd(), "tests"))
from conftest import linear_population_setup, assert_trees_bitwise
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.mobility import compact_colocation
from repro.scenarios import get_scenario, run_population_streamed

M, T = 8, 96
co = get_scenario("multi_area_migratory").colocation(0, M, T)
pop, _, batch_fn, train_fn, pcfg = linear_population_setup(
    n_mules=M, n_steps=T)
mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:4]).reshape(1, 4), ("pod", "data"))

def run(method, prune):
    dcfg = DistributedConfig(pop=pcfg, ring_prune=prune,
                             rebucket_every=16, rebucket_threshold=0.1)
    return run_population_streamed(
        to_distributed_state(pop, dcfg), compact_colocation(co), batch_fn,
        train_fn, pcfg, jax.random.PRNGKey(7), n_steps=T, chunk_len=16,
        method=method, donate=False, mesh=mesh, dcfg=dcfg)

for method, tol in (("oppcl", 0.0), ("gossip", 1e-5)):
    pruned, aux_p = run(method, True)
    full, aux_f = run(method, False)
    assert aux_p["rebucket"]["swaps"] >= 1, aux_p["rebucket"]
    np.testing.assert_array_equal(aux_p["rebucket"]["order"],
                                  aux_f["rebucket"]["order"])
    if tol == 0.0:
        assert_trees_bitwise(pruned, full, method)
    else:
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                  zip(jax.tree.leaves(pruned["mule_models"]),
                      jax.tree.leaves(full["mule_models"])))
        assert err <= tol, (method, err)
print("OK")
""", n_devices=4)


@pytest.mark.slow
def test_migrate_mule_state_full_pytree_roundtrip(multi_device_runner):
    """n_pods applications of migrate_mule_state walk every flagged mule's
    *entire* state — models, timestamps — around the pod ring bitwise,
    while replicated leaves never move."""
    multi_device_runner("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import migrate_mule_state

mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:4]).reshape(2, 2), ("pod", "data"))
state = {
    "mule_models": {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 3))},
    "mule_ts": jnp.arange(8),
    "fixed_models": {"w": jnp.ones((4, 3))},
    "mule_opt": None,
}
mask = jnp.array([True, False] * 4)
out = dict(state)
for _ in range(2):                       # n_pods applications round-trip
    out = migrate_mule_state(out, mask, mesh)
once = migrate_mule_state(state, mask, mesh)
assert once["mule_opt"] is None          # absent carry stays absent
for k in ("mule_models", "mule_ts"):
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(out[k])[0]),
        np.asarray(jax.tree.leaves(state[k])[0]), err_msg=k)
assert once["fixed_models"]["w"] is state["fixed_models"]["w"]
print("ok")
""", n_devices=4)
