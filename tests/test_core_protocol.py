"""ML Mule core: freshness filter math, protocol cycles, engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.core.aggregation import pairwise_mix
from repro.core.freshness import (FreshnessConfig, accept_mask, init_freshness,
                                  push_and_update)
from repro.core.population import PopulationConfig, init_population, population_step
from repro.core.protocol import (DeviceState, fixed_device_training_cycle,
                                 mobile_device_training_cycle)


def _linear_model(k):
    return {"w": jax.random.normal(k, (4,))}


def test_freshness_threshold_formula():
    """T' = (1-a)T + a(median + b*MAD) — checked against numpy."""
    cfg = FreshnessConfig(alpha=0.25, beta=1.5, history=8, warmup=0,
                          init_threshold=100.0)
    state = init_freshness(2, cfg)
    ages = jnp.array([3.0, 5.0, 7.0, 100.0])
    fids = jnp.array([0, 0, 0, 1], jnp.int32)
    deliver = jnp.array([True, True, True, True])
    new = push_and_update(state, fids, ages, deliver, cfg)
    med = np.median([3, 5, 7])
    mad = np.median(np.abs(np.array([3, 5, 7]) - med))
    want0 = 0.75 * 100.0 + 0.25 * (med + 1.5 * mad)
    np.testing.assert_allclose(float(new["threshold"][0]), want0, rtol=1e-6)
    want1 = 0.75 * 100.0 + 0.25 * (100.0 + 1.5 * 0.0)
    np.testing.assert_allclose(float(new["threshold"][1]), want1, rtol=1e-6)


def test_freshness_rejects_stale_accepts_fresh():
    cfg = FreshnessConfig(warmup=0, init_threshold=10.0)
    state = init_freshness(1, cfg)
    fids = jnp.array([0, 0], jnp.int32)
    ages = jnp.array([5.0, 50.0])
    ok = accept_mask(state, fids, ages, cfg)
    assert bool(ok[0]) and not bool(ok[1])


def test_warmup_accepts_everything():
    cfg = FreshnessConfig(warmup=4, init_threshold=0.0)
    state = init_freshness(1, cfg)
    ok = accept_mask(state, jnp.array([0], jnp.int32), jnp.array([1e9]), cfg)
    assert bool(ok[0])


@settings(max_examples=20, deadline=None)
@given(ages=st.lists(st.floats(0, 1000), min_size=1, max_size=6),
       alpha=st.floats(0.01, 0.99), beta=st.floats(0.0, 3.0))
def test_freshness_threshold_bounded(ages, alpha, beta):
    """Threshold stays within [min(T0, target), max(T0, target)] — EMA
    cannot overshoot the (median + beta*MAD) target."""
    cfg = FreshnessConfig(alpha=alpha, beta=beta, history=8, warmup=0,
                          init_threshold=50.0)
    state = init_freshness(1, cfg)
    fids = jnp.zeros((len(ages),), jnp.int32)
    new = push_and_update(state, fids, jnp.array(ages, jnp.float32),
                          jnp.ones((len(ages),), bool), cfg)
    med = float(np.median(ages))
    mad = float(np.median(np.abs(np.array(ages) - med)))
    target = med + beta * mad
    lo, hi = min(50.0, target) - 1e-3, max(50.0, target) + 1e-3
    assert lo <= float(new["threshold"][0]) <= hi


def test_protocol_cycles_match_paper_order():
    """Fixed-device cycle trains AFTER aggregation; mobile cycle trains the
    mule AFTER receiving the aggregate. Both stamp timestamps to t."""
    t = jnp.float32(10.0)
    mule = DeviceState({"w": jnp.ones(3)}, jnp.float32(4.0))
    fixed = DeviceState({"w": jnp.zeros(3)}, jnp.float32(9.0))
    train = lambda m: {"w": m["w"] + 100.0}

    new_m, new_f, acc = fixed_device_training_cycle(
        mule, fixed, jnp.float32(100.0), t, train, gamma=0.5)
    assert bool(acc)
    # f aggregated to 0.5 then trained (+100) -> 100.5; m mixes 1 and 100.5
    np.testing.assert_allclose(np.asarray(new_f.model["w"]), 100.5)
    np.testing.assert_allclose(np.asarray(new_m.model["w"]), 0.5 * 1 + 0.5 * 100.5)
    assert float(new_m.ts) == 10.0 and float(new_f.ts) == 10.0

    new_m, new_f, acc = mobile_device_training_cycle(
        mule, fixed, jnp.float32(100.0), t, train, gamma=0.5)
    np.testing.assert_allclose(np.asarray(new_f.model["w"]), 0.5)   # no train at f
    np.testing.assert_allclose(np.asarray(new_m.model["w"]), 100.75)  # trained last


def test_stale_model_does_not_contaminate():
    """A rejected (stale) mule snapshot must leave the fixed model unchanged."""
    t = jnp.float32(1000.0)
    mule = DeviceState({"w": jnp.full(3, 77.0)}, jnp.float32(0.0))  # age 1000
    fixed = DeviceState({"w": jnp.zeros(3)}, t)
    new_m, new_f, acc = mobile_device_training_cycle(
        mule, fixed, jnp.float32(10.0), t, lambda m: m, gamma=0.5)
    assert not bool(acc)
    np.testing.assert_allclose(np.asarray(new_f.model["w"]), 0.0)


def test_population_step_matches_single_pair_protocol():
    """One mule delivering to one fixed device: the vectorized engine must
    reproduce the per-pair protocol semantics exactly (fixed-device mode)."""
    pcfg = PopulationConfig(
        mode="fixed", n_fixed=2, n_mules=1, gamma=0.5,
        freshness=FreshnessConfig(warmup=0, init_threshold=1e9))
    state = init_population(jax.random.PRNGKey(0), _linear_model, pcfg)
    state = dict(state, t=jnp.float32(5.0))
    train = lambda p, b, k: {"w": p["w"] + 1.0}
    info = {"fixed_id": jnp.array([0], jnp.int32), "exchange": jnp.array([True])}
    batches = {"fixed": jnp.zeros((2, 1)), "mule": None}
    new = population_step(state, info, batches, train, pcfg, jax.random.PRNGKey(1))

    w_m = state["mule_models"]["w"][0]
    w_f = state["fixed_models"]["w"][0]
    f_expected = 0.5 * w_f + 0.5 * w_m + 1.0      # aggregate then train
    m_expected = 0.5 * w_m + 0.5 * f_expected
    np.testing.assert_allclose(np.asarray(new["fixed_models"]["w"][0]),
                               np.asarray(f_expected), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new["mule_models"]["w"][0]),
                               np.asarray(m_expected), rtol=1e-6)
    # untouched fixed device 1 must not train or move
    np.testing.assert_allclose(np.asarray(new["fixed_models"]["w"][1]),
                               np.asarray(state["fixed_models"]["w"][1]))
    assert float(new["mule_ts"][0]) == 5.0


def test_mule_carries_model_between_spaces():
    """Space-coupled, time-decoupled transfer: a model trained at space A
    reaches space B only via the mule (integration test of the core claim)."""
    pcfg = PopulationConfig(
        mode="fixed", n_fixed=2, n_mules=1, gamma=1.0,
        freshness=FreshnessConfig(warmup=10, init_threshold=1e9))
    state = init_population(jax.random.PRNGKey(0), _linear_model, pcfg)
    # the mule carries a signature model (e.g. trained at space A earlier)
    state["mule_models"]["w"] = jnp.full((1, 4), 42.0)
    train = lambda p, b, k: p  # no training; isolate transport semantics
    batches = {"fixed": jnp.zeros((2, 1)), "mule": None}

    # step 1: corridor (no co-location) — nothing changes anywhere
    info = {"fixed_id": jnp.array([-1], jnp.int32), "exchange": jnp.array([False])}
    s1 = population_step(dict(state), info, batches, train, pcfg,
                         jax.random.PRNGKey(2))
    np.testing.assert_allclose(np.asarray(s1["fixed_models"]["w"]),
                               np.asarray(state["fixed_models"]["w"]))
    np.testing.assert_allclose(np.asarray(s1["mule_models"]["w"][0]), 42.0)

    # step 2: mule reaches device 1 -> drops the model off (gamma=1)
    info = {"fixed_id": jnp.array([1], jnp.int32), "exchange": jnp.array([True])}
    s2 = population_step(s1, info, batches, train, pcfg, jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(s2["fixed_models"]["w"][1]), 42.0)
    # device 0 never met the mule and is untouched
    np.testing.assert_allclose(np.asarray(s2["fixed_models"]["w"][0]),
                               np.asarray(state["fixed_models"]["w"][0]))


@settings(max_examples=15, deadline=None)
@given(gamma=st.floats(0.0, 1.0), seed=st.integers(0, 1000))
def test_pairwise_mix_convexity(gamma, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = {"w": jax.random.normal(k1, (5,))}
    b = {"w": jax.random.normal(k2, (5,))}
    out = pairwise_mix(a, b, gamma)["w"]
    lo = jnp.minimum(a["w"], b["w"]) - 1e-6
    hi = jnp.maximum(a["w"], b["w"]) + 1e-6
    assert bool(jnp.all((out >= lo) & (out <= hi)))
