"""Locality-aware ring exchange: the hop-prune predicate, bucket-sharding
helpers, the per-hop Pallas kernel, and pruned-vs-full ring parity.

The safety property the tier-1 half pins is one-sided: the area-bitmask
predicate may EXECUTE a hop it didn't need (hash collisions of
``area % N_AREA_BITS`` only add work), but it must never PRUNE a hop whose
two shard blocks share an active area — that would silently drop
encounters. The slow half replays every registered multi-area scenario
through the real sharded engine with pruning on and off and demands the
results agree (bitwise for oppcl, whose skipped hops leave its running
argmin untouched; to float tolerance for the mean-mix methods).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # tier-1 container
    from repro.testing.hypo import given, settings, strategies as st

from repro.baselines.gossip import (N_AREA_BITS, RingSpec, area_bits,
                                    hops_needed, ring_hop_mask)
from repro.core.distributed import (bucket_locality_fraction,
                                    bucket_mule_order, reorder_colocation,
                                    reorder_mule_state)


# ---------------------------------------------------------------------------
# hop-prune predicate
# ---------------------------------------------------------------------------


def test_area_bits_is_active_onehot_union():
    area = jnp.array([0, 1, 33, 5], jnp.int32)        # 33 collides with 1
    bits = np.asarray(area_bits(area))
    assert bits.shape == (N_AREA_BITS,)
    assert set(np.nonzero(bits)[0]) == {0, 1, 5}
    act = jnp.array([True, False, False, True])
    bits = np.asarray(area_bits(area, act))
    assert set(np.nonzero(bits)[0]) == {0, 5}          # inactive rows drop out
    assert not np.asarray(area_bits(area, jnp.zeros(4, bool))).any()


def test_hop_mask_prunes_disjoint_buckets():
    # bucket-ordered: one area per shard block -> only the local hop runs
    n, m = 8, 4
    area = np.repeat(np.arange(n, dtype=np.int32), m)
    mask = np.asarray(ring_hop_mask(area, None, n))
    assert mask.shape == (n,)
    assert mask[0] and not mask[1:].any()
    # shuffled mules defeat the predicate: every block holds every area
    rng = np.random.RandomState(0)
    mask = np.asarray(ring_hop_mask(rng.permutation(area), None, n))
    assert mask.all()


@settings(max_examples=60, deadline=None)
@given(n_shards=st.sampled_from([2, 4, 8]),
       m_loc=st.integers(1, 4),
       n_areas=st.integers(1, 40),
       seed=st.integers(0, 10 ** 6),
       p_active=st.floats(0.0, 1.0))
def test_hop_mask_never_prunes_a_shared_area_hop(n_shards, m_loc, n_areas,
                                                 seed, p_active):
    """Soundness: if ANY active row of shard i shares an area with any
    active row of shard (i - s) % n, hop s must be kept. (The converse is
    not required — ``area % 32`` collisions may keep extra hops.)"""
    rng = np.random.RandomState(seed)
    m = n_shards * m_loc
    area = rng.randint(0, n_areas, size=m).astype(np.int32)
    active = rng.rand(m) < p_active
    mask = np.asarray(ring_hop_mask(area, active, n_shards))
    blocks = [(set(area[k * m_loc:(k + 1) * m_loc]
                   [active[k * m_loc:(k + 1) * m_loc]]))
              for k in range(n_shards)]
    for s in range(n_shards):
        needed = any(blocks[i] & blocks[(i - s) % n_shards]
                     for i in range(n_shards))
        if needed:
            assert mask[s], (s, blocks)


def test_hops_needed_matches_pairwise_bit_intersection():
    all_bits = jnp.array([[1, 0, 0, 0], [0, 1, 0, 0],
                          [1, 0, 0, 0], [0, 0, 1, 0]], bool)
    # shift 2 pairs shard 2 with shard 0 (both bit 0); shift 1 and 3 pair
    # only disjoint rows
    assert np.asarray(hops_needed(all_bits)).tolist() == \
        [True, False, True, False]


def test_ring_spec_shift_perm_routes_shard_i_minus_s():
    ring = RingSpec(axis_name="data", axis_size=4)
    assert ring.shift_perm(1) == [(0, 1), (1, 2), (2, 3), (3, 0)]
    # receiving side of shift s on shard i is (i - s) % n — the col0 rule
    for s in range(4):
        for src, dst in ring.shift_perm(s):
            assert src == (dst - s) % 4


# ---------------------------------------------------------------------------
# bucket sharding helpers
# ---------------------------------------------------------------------------


def test_bucket_order_groups_areas_and_reorders_consistently():
    rng = np.random.RandomState(1)
    m, t = 12, 5
    area = rng.randint(0, 3, size=m).astype(np.int32)
    order = bucket_mule_order(area)
    sorted_area = area[order]
    assert (np.diff(sorted_area) >= 0).all()           # grouped by bucket
    # stable: equal areas keep their original relative order
    for a in np.unique(area):
        assert (np.diff(order[sorted_area == a]) > 0).all()
    co = {"fixed_id": rng.randint(-1, 4, size=(t, m)).astype(np.int32),
          "exchange": rng.rand(t, m) < 0.5,
          "pos": rng.rand(t, m, 2).astype(np.float32),
          "area": area, "init_space": rng.randint(0, 4, size=m)}
    out = reorder_colocation(co, order)
    assert np.array_equal(out["area"], sorted_area)
    assert np.array_equal(out["fixed_id"], co["fixed_id"][:, order])
    assert np.array_equal(out["pos"], co["pos"][:, order])
    assert np.array_equal(out["init_space"], co["init_space"][order])
    state = {"mule_models": {"w": np.arange(m * 2.).reshape(m, 2)},
             "mule_ts": np.arange(m), "t": np.int32(3)}
    sout = reorder_mule_state(state, order)
    assert np.array_equal(sout["mule_models"]["w"],
                          state["mule_models"]["w"][order])
    assert np.array_equal(sout["mule_ts"], state["mule_ts"][order])
    assert sout["t"] == state["t"]                     # non-mule leaves kept


def test_bucket_locality_fraction_bounds():
    area = np.repeat(np.arange(4, dtype=np.int32), 4)
    assert bucket_locality_fraction(area, 4) == 1.0    # bucketed: all local
    inter = np.tile(np.arange(4, dtype=np.int32), 4)
    assert bucket_locality_fraction(inter, 4) == 0.0   # striped: none local
    assert bucket_locality_fraction(np.zeros(8, np.int32), 1) == 1.0
    frac = bucket_locality_fraction(inter[bucket_mule_order(inter)], 4)
    assert frac == 1.0                                 # ordering restores it


# ---------------------------------------------------------------------------
# per-hop kernel vs the block oracle
# ---------------------------------------------------------------------------


def _hop_case(seed, r, v, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    return (jax.random.uniform(ks[0], (r, 2)),
            jax.random.randint(ks[1], (r,), 0, 3),
            jax.random.uniform(ks[2], (r,)) < 0.8,
            jax.random.uniform(ks[3], (v, 2)),
            jax.random.randint(ks[4], (v,), 0, 3),
            jax.random.uniform(ks[5], (v,)) < 0.8,
            jax.random.normal(ks[6], (v, d)))


@pytest.mark.parametrize("r,v,d,row0,col0", [
    (16, 16, 48, 0, 0),        # self block: diagonal excluded
    (16, 16, 48, 16, 48),      # disjoint offsets
    (12, 20, 7, 0, 8),         # overlapping id ranges, ragged shapes
    (8, 8, 8, 24, 24),
])
def test_hop_kernel_matches_block_oracle(r, v, d, row0, col0):
    from repro.kernels.encounter_mix.kernel import encounter_hop_pallas
    from repro.kernels.encounter_mix.ref import encounter_block
    pos_r, area_r, act_r, pos_v, area_v, act_v, w = _hop_case(0, r, v, d)
    acc_ref, mass_ref = encounter_block(pos_r, area_r, act_r, row0,
                                        pos_v, area_v, act_v, col0,
                                        w, 0.3)
    acc, mass = encounter_hop_pallas(pos_r, area_r, act_r, row0,
                                     pos_v, area_v, act_v, col0, w,
                                     radius=0.3, block_m=8, block_d=128,
                                     interpret=True)
    assert mass_ref.sum() > 0                          # non-degenerate case
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(mass_ref),
                               rtol=1e-6, atol=1e-6)


def test_encounter_block_hop_dispatch():
    from repro.kernels.encounter_mix.ops import encounter_block_hop
    from repro.kernels.encounter_mix.ref import encounter_block
    pos_r, area_r, act_r, pos_v, area_v, act_v, w = _hop_case(1, 16, 16, 32)
    ref = encounter_block(pos_r, area_r, act_r, 0, pos_v, area_v, act_v, 16,
                          w, 0.3)
    out = encounter_block_hop(pos_r, area_r, act_r, 0,
                              pos_v, area_v, act_v, 16, w, 0.3,
                              backend="ref")
    for a, b in zip(out, ref):                         # ref IS the oracle
        assert np.array_equal(np.asarray(a), np.asarray(b))
    out = encounter_block_hop(pos_r, area_r, act_r, 0,
                              pos_v, area_v, act_v, 16, w, 0.3,
                              backend="interpret")
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        encounter_block_hop(pos_r, area_r, act_r, 0,
                            pos_v, area_v, act_v, 16, w, 0.3,
                            backend="nope")


# ---------------------------------------------------------------------------
# engine plumbing on the single local device (fast tier)
# ---------------------------------------------------------------------------


def _tiny_mobile_setup(m=8, t=6):
    from conftest import linear_population_setup
    return linear_population_setup("mobile", n_mules=m, n_steps=t,
                                   init_threshold=1e9, warmup=10 ** 6)


def test_ring_prune_flag_is_identity_on_one_device():
    from repro.core.distributed import (DistributedConfig,
                                        to_distributed_state)
    from repro.launch.mesh import make_mule_mesh
    from repro.scenarios import run_population_distributed
    import dataclasses
    pop, co, batch_fn, train_fn, pcfg = _tiny_mobile_setup()
    mesh = make_mule_mesh(1, 1)
    key = jax.random.PRNGKey(2)
    outs = []
    for prune in (True, False):
        dcfg = dataclasses.replace(DistributedConfig(pop=pcfg),
                                   ring_prune=prune)
        f, _ = run_population_distributed(to_distributed_state(pop, dcfg),
                                          co, batch_fn, train_fn, dcfg,
                                          mesh, key, method="gossip")
        outs.append(f["mule_models"])
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_mesh_none_uses_the_suggested_shape():
    from repro.core.distributed import (DistributedConfig,
                                        to_distributed_state)
    from repro.launch.mesh import make_mule_mesh
    from repro.scenarios import run_population_distributed
    pop, co, batch_fn, train_fn, pcfg = _tiny_mobile_setup()
    dcfg = DistributedConfig(pop=pcfg)
    dstate = to_distributed_state(pop, dcfg)
    key = jax.random.PRNGKey(4)
    auto, _ = run_population_distributed(dstate, co, batch_fn, train_fn,
                                         dcfg, None, key, method="gossip")
    explicit, _ = run_population_distributed(dstate, co, batch_fn, train_fn,
                                             dcfg, make_mule_mesh(1, 1), key,
                                             method="gossip")
    # one host device -> the auto path can only pick the (1, 1) mesh, so
    # the runs must be the same program: bitwise
    for a, b in zip(jax.tree.leaves(auto), jax.tree.leaves(explicit)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# pruned vs full ring on a real mesh, every registered multi-area scenario
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pruned_ring_parity_every_multi_area_scenario(multi_device_runner):
    """For each registered scenario whose colocation spans > 1 area, run
    gossip / oppcl / mlmule+gossip on a real (1, 4) data mesh with hop
    pruning on and off: oppcl must agree bitwise, the mean-mix methods to
    1e-5 (in practice a pruned hop contributes an exact +0.0, so these
    agree bitwise too). A bucket-ordered variant must actually prune."""
    multi_device_runner("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.baselines.gossip import ring_hop_mask
from repro.core.distributed import (DistributedConfig, bucket_mule_order,
                                    reorder_colocation, to_distributed_state)
from repro.core.freshness import FreshnessConfig
from repro.core.population import PopulationConfig, init_population
from repro.scenarios import (SCENARIOS, run_population_distributed)

F, M, T = 12, 8, 9
mesh = jax.make_mesh((1, 4), ("pod", "data"))
X = jax.random.normal(jax.random.PRNGKey(50), (M, 12, 5))
Y = jax.random.normal(jax.random.PRNGKey(60), (M, 12))

def train_fn(params, batch, key):
    xb, yb = batch
    g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
    return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

def batch_fn(key, t):
    idx = jax.random.randint(key, (M, 4), 0, X.shape[1])
    return {"fixed": None, "mule": (jnp.take_along_axis(X, idx[:, :, None], 1),
                                    jnp.take_along_axis(Y, idx, 1))}

pcfg = PopulationConfig(mode="mobile", n_fixed=F, n_mules=M,
                        freshness=FreshnessConfig(init_threshold=1e9,
                                                  warmup=10**6))
pop = init_population(jax.random.PRNGKey(0),
                      lambda k: {"w": jax.random.normal(k, (5,))}, pcfg)
dcfg = DistributedConfig(pop=pcfg)
dcfg_u = dataclasses.replace(dcfg, ring_prune=False)
dstate = to_distributed_state(pop, dcfg)
key = jax.random.PRNGKey(7)

multi = []
for name, spec in sorted(SCENARIOS.items()):
    co = spec.colocation(3, M, T)
    if len(np.unique(np.asarray(co["area"]))) < 2:
        continue
    multi.append(name)
    co = reorder_colocation(co, bucket_mule_order(co["area"]))
    for method in ("gossip", "oppcl", "mlmule+gossip"):
        fp, _ = run_population_distributed(dstate, co, batch_fn, train_fn,
                                           dcfg, mesh, key, method=method)
        fu, _ = run_population_distributed(dstate, co, batch_fn, train_fn,
                                           dcfg_u, mesh, key, method=method)
        for a, b in zip(jax.tree.leaves(fp["mule_models"]),
                        jax.tree.leaves(fu["mule_models"])):
            a, b = np.asarray(a), np.asarray(b)
            if method == "oppcl":
                assert np.array_equal(a, b), (name, method)
            else:
                err = float(np.max(np.abs(a - b)))
                assert err < 1e-5, (name, method, err)
assert multi, "no multi-area scenario registered?"

# the registered traces are area-0 heavy (one area spans >= 3 of the 4
# blocks, so every hop is genuinely needed); a BALANCED bucket-ordered
# multi-area population must actually prune on this mesh
area = np.asarray([0] * 4 + [1] * 4 + [2] * 4 + [3] * 4, np.int32)
mask = np.asarray(ring_hop_mask(area, None, 4))
assert mask[0] and (~mask).sum() == 3, mask.tolist()
print("OK", multi)
""", n_devices=4)
