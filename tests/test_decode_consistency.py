"""Decode-vs-forward consistency: KV caches, SSM states, xLSTM states and
rolling-window caches must reproduce full-sequence logits token by token."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

STRICT = [a for a in ARCH_IDS if a != "qwen2-vl-72b"]


def _fp32_dropfree(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.n_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    return cfg


@pytest.mark.parametrize("arch", STRICT)
def test_decode_matches_forward(arch):
    cfg = _fp32_dropfree(get_smoke_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["audio_embed"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model))
    full, _ = model.forward(params, batch)
    cache = model.init_cache(b, s, dtype=jnp.float32)
    if cfg.family == "audio":
        cache = model.prefill_cross_kv(params, batch["audio_embed"], cache)
    errs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-4, errs


def test_vlm_prefill_then_decode():
    """qwen2-vl: decode continues correctly after a vision-prefixed prefill."""
    cfg = _fp32_dropfree(get_smoke_config("qwen2-vl-72b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s_text = 2, 8
    vt = cfg.vision_tokens
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s_text), 0, cfg.vocab)
    ve = 0.1 * jax.random.normal(jax.random.PRNGKey(6), (b, vt, cfg.d_model))
    full, _ = model.forward(params, {"tokens": toks, "vision_embed": ve})
    # decode path: replay text tokens one by one against a cache that was
    # "prefilled" by running decode over the vision positions is not defined
    # for stub embeddings; instead check text-only consistency:
    cfg_txt = dataclasses.replace(cfg, vision_tokens=0, family="dense",
                                  mrope_sections=None)
    model_txt = build_model(cfg_txt)
    full_txt, _ = model_txt.forward(params, {"tokens": toks})
    cache = model_txt.init_cache(b, s_text, dtype=jnp.float32)
    errs = []
    for t in range(s_text):
        lg, cache = model_txt.decode_step(params, cache, toks[:, t:t + 1],
                                          jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full_txt[:, t]))))
    assert max(errs) < 2e-4


def test_sliding_window_cache_rolls():
    """gemma3-style local layers: decode past the window uses the rolling
    buffer and still matches full-sequence forward."""
    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"), dtype="float32")
    assert cfg.sliding_window and cfg.sliding_window < 128
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, cfg.sliding_window + 24   # force wraparound
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(b, s, dtype=jnp.float32)
    errs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-4, max(errs)
