"""Fused sLSTM recurrence kernel: interpret-mode vs oracle + model parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm_fused.kernel import slstm_scan_pallas
from repro.kernels.slstm_fused.ref import slstm_reference


@pytest.mark.parametrize("b,s,h,p", [(2, 24, 3, 8), (1, 7, 1, 4), (2, 33, 4, 16)])
def test_pallas_matches_oracle(b, s, h, p):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    pre = jax.random.normal(ks[0], (b, s, 4, h, p))
    r = 0.1 * jax.random.normal(ks[1], (4, h, p, p))
    href, _ = slstm_reference(pre, r)
    hpal = slstm_scan_pallas(pre, r, interpret=True)
    np.testing.assert_allclose(np.asarray(hpal), np.asarray(href), atol=2e-6)


def test_state_carry_matches_split_scan():
    """Scanning two halves with explicit state == one full scan."""
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    pre = jax.random.normal(ks[0], (1, 16, 4, 2, 8))
    r = 0.1 * jax.random.normal(ks[1], (4, 2, 8, 8))
    h_full, _ = slstm_reference(pre, r)
    h1, st = slstm_reference(pre[:, :8], r)
    h2, _ = slstm_reference(pre[:, 8:], r, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=1)), np.asarray(h_full),
        atol=1e-6)


def test_model_path_uses_kernel_consistently():
    """xlstm forward with backend=interpret (kernel) == backend=ref (scan)."""
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_smoke_config("xlstm-350m"), dtype="float32")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    l_ref, _ = build_model(cfg, backend="ref").forward(params, {"tokens": toks})
    l_pal, _ = build_model(cfg, backend="interpret").forward(
        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref), atol=5e-4)
