"""Mobility model invariants (paper Sec 4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.mobility import (MobilityConfig, init_mobility, mobility_step,
                            simulate_trajectories, space_of,
                            synth_foursquare_trace, trace_to_colocation)


def test_positions_stay_in_bounds():
    cfg = MobilityConfig(n_mules=16, p_cross=0.5)
    infos = simulate_trajectories(jax.random.PRNGKey(0), cfg, 200)
    pos = np.asarray(infos["pos"])
    assert (pos >= 0).all() and (pos <= 1).all()


def test_p_cross_zero_never_leaves():
    """P_cross = 0 -> devices never leave their starting space (paper)."""
    cfg = MobilityConfig(n_mules=12, p_cross=0.0)
    state = init_mobility(jax.random.PRNGKey(1), cfg)
    start = np.asarray(space_of(state["pos"], cfg.space_size))
    assert (start >= 0).all()
    infos = simulate_trajectories(jax.random.PRNGKey(1), cfg, 300)
    spaces = np.asarray(infos["space"])
    for m in range(cfg.n_mules):
        seen = set(spaces[:, m].tolist())
        assert seen == {start[m]}, (m, seen, start[m])


def test_higher_p_cross_more_movement():
    def distinct_spaces(p):
        cfg = MobilityConfig(n_mules=20, p_cross=p)
        infos = simulate_trajectories(jax.random.PRNGKey(2), cfg, 400)
        s = np.asarray(infos["space"])
        return np.mean([len(set(s[:, m].tolist()) - {-1})
                        for m in range(20)])
    assert distinct_spaces(0.5) > distinct_spaces(0.01)


def test_exchange_cadence():
    """Exchanges fire exactly every `exchange_steps` consecutive co-located
    steps — the paper's 3-step model-transfer latency."""
    cfg = MobilityConfig(n_mules=8, p_cross=0.0, exchange_steps=3)
    infos = simulate_trajectories(jax.random.PRNGKey(3), cfg, 30)
    exch = np.asarray(infos["exchange"])
    # with p_cross=0 all mules stay co-located: dwell = 1,2,3,... ->
    # exchanges at steps where dwell % 3 == 0
    for m in range(8):
        fired = np.where(exch[:, m])[0]
        assert len(fired) == 10, fired
        assert (np.diff(fired) == 3).all()


def test_area_isolation():
    cfg = MobilityConfig(n_mules=10, n_areas=2, p_cross=0.5)
    state = init_mobility(jax.random.PRNGKey(4), cfg)
    areas0 = np.asarray(state["area"])
    infos = simulate_trajectories(jax.random.PRNGKey(4), cfg, 100)
    fid = np.asarray(infos["fixed_id"])
    for m in range(10):
        ids = fid[:, m]
        ids = ids[ids >= 0]
        if len(ids):
            assert ((ids // 4) == areas0[m]).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_space_of_partition(seed):
    """Every point is in exactly one region (space 0-3 or corridor)."""
    pos = jax.random.uniform(jax.random.PRNGKey(seed), (100, 2))
    sid = np.asarray(space_of(pos, 0.42))
    assert ((sid >= -1) & (sid <= 3)).all()


def test_trace_expansion():
    visits = synth_foursquare_trace(0, n_users=10, n_places=8, n_steps=500)
    assert len(visits) > 0
    fid, exch = trace_to_colocation(visits, 10, 500, exchange_steps=3)
    assert fid.shape == (500, 10)
    # exchanges only while co-located
    assert not np.any(exch & (fid < 0))
    # transient users exist (sparsity property the paper highlights)
    visits_per_user = np.bincount(visits[:, 0], minlength=10)
    assert visits_per_user.min() < visits_per_user.max()
