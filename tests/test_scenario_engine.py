"""Scan engine parity: run_population vs a hand-rolled Python loop of
population_step (bitwise), and single-host vs distributed aggregation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mule_cnn import CNNConfig
from repro.core.distributed import DistributedConfig, to_distributed_state
from repro.core.freshness import FreshnessConfig
from repro.core.population import (PopulationConfig, init_population,
                                   population_step)
from repro.mobility import commuter_trace
from repro.models.cnn import cnn_forward, init_cnn, xent_loss
from repro.scenarios import run_population, trace_colocation

F, M, T = 4, 5, 25


def _tiny_cnn_setup(mode):
    mc = CNNConfig(image_size=4, conv_features=(2, 2), hidden=8, n_classes=4)
    n = F if mode == "fixed" else M
    X = jax.random.normal(jax.random.PRNGKey(3), (n, 12, 4, 4, 3))
    Y = jax.random.randint(jax.random.PRNGKey(4), (n, 12), 0, 4)

    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: xent_loss(cnn_forward(p, xb), yb))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(key, t):
        idx = jax.random.randint(key, (n, 3), 0, X.shape[1])
        b = (jnp.take_along_axis(X, idx[:, :, None, None, None], 1),
             jnp.take_along_axis(Y, idx, 1))
        return ({"fixed": b, "mule": None} if mode == "fixed"
                else {"fixed": None, "mule": b})

    pcfg = PopulationConfig(mode=mode, n_fixed=F, n_mules=M)
    pop = init_population(jax.random.PRNGKey(0),
                          lambda k: init_cnn(k, mc), pcfg)
    co = trace_colocation(commuter_trace(0, n_users=M, n_places=F,
                                         n_steps=T, period=10, commute=1),
                          M, T)
    assert (co["exchange"] & (co["fixed_id"] >= 0)).any(), "dead schedule"
    return pop, co, batch_fn, train_fn, pcfg


def _hand_loop(pop, co, batch_fn, train_fn, pcfg, key, n_steps):
    """Replicates the engine's documented key discipline exactly."""
    step = jax.jit(lambda s, i, b, k: population_step(
        s, i, b, train_fn, pcfg, k))
    for t in range(n_steps):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        info = {"fixed_id": jnp.asarray(co["fixed_id"][t]),
                "exchange": jnp.asarray(co["exchange"][t])}
        pop = step(pop, info, batch_fn(kb, t), ks)
    return pop


def _assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), \
            "scan and loop drivers diverged"


def test_engine_bitwise_matches_loop_fixed_mode():
    pop, co, batch_fn, train_fn, pcfg = _tiny_cnn_setup("fixed")
    key = jax.random.PRNGKey(7)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    ref = _hand_loop(pop, co, batch_fn, train_fn, pcfg, key, T)
    _assert_trees_bitwise(final, ref)
    # last_fid tracks each mule's most recent co-location
    fid = co["fixed_id"]
    want = np.zeros(M, np.int32)
    for t in range(T):
        want = np.where(fid[t] >= 0, fid[t], want)
    np.testing.assert_array_equal(np.asarray(aux["last_fid"]), want)


def test_engine_bitwise_matches_loop_mobile_mode():
    pop, co, batch_fn, train_fn, pcfg = _tiny_cnn_setup("mobile")
    key = jax.random.PRNGKey(11)
    final, _ = run_population(pop, co, batch_fn, train_fn, pcfg, key)
    ref = _hand_loop(pop, co, batch_fn, train_fn, pcfg, key, T)
    _assert_trees_bitwise(final, ref)


def test_engine_in_scan_eval_and_partial_tail():
    """eval_every=10 over T=25: two in-scan evals + a 5-step tail, with the
    final state still bitwise-identical to the full loop."""
    pop, co, batch_fn, train_fn, pcfg = _tiny_cnn_setup("fixed")
    key = jax.random.PRNGKey(13)
    final, aux = run_population(
        pop, co, batch_fn, train_fn, pcfg, key, eval_every=10,
        eval_fn=lambda st, last: jnp.mean(st["fixed_models"]["fc2"]))
    np.testing.assert_array_equal(aux["eval_steps"], [9, 19])
    assert np.asarray(aux["evals"]).shape == (2,)
    ref = _hand_loop(pop, co, batch_fn, train_fn, pcfg, key, T)
    _assert_trees_bitwise(final, ref)
    # eval at step 9 must equal the metric on a 10-step loop state
    ref10 = _hand_loop(pop, co, batch_fn, train_fn, pcfg, key, 10)
    np.testing.assert_array_equal(
        np.asarray(aux["evals"])[0],
        np.asarray(jnp.mean(ref10["fixed_models"]["fc2"])))


def test_engine_stacked_batches_path():
    """Precomputed [T, ...] batches scan as xs; training key is fold_in(key, t)."""
    pop, co, batch_fn, train_fn, pcfg = _tiny_cnn_setup("fixed")
    key = jax.random.PRNGKey(17)
    stacked = jax.tree.map(
        lambda *ls: jnp.stack(ls),
        *[batch_fn(jax.random.PRNGKey(100 + t), t) for t in range(T)])
    final, _ = run_population(pop, co, stacked, train_fn, pcfg, key)

    step = jax.jit(lambda s, i, b, k: population_step(
        s, i, b, train_fn, pcfg, k))
    ref = pop
    for t in range(T):
        info = {"fixed_id": jnp.asarray(co["fixed_id"][t]),
                "exchange": jnp.asarray(co["exchange"][t])}
        bt = jax.tree.map(lambda l: l[t], stacked)
        ref = step(ref, info, bt, jax.random.fold_in(key, t))
    _assert_trees_bitwise(final, ref)


def test_distributed_step_matches_single_host_aggregation():
    """The parity the distributed.py docstring promises: with the freshness
    filter accepting everything, the distributed method step — the fused
    ``encounter_mix`` collective schedule, the only distributed encounter
    path — and the single-host engine agree on aggregation (single-device
    mesh, in-process, driven one dispatch per step by
    ``run_population_distributed_loop``)."""
    from repro.scenarios import run_population_distributed_loop
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))
    n_fixed, n_mules = 4, 8

    def init_model(k):
        return {"w": jax.random.normal(k, (3, 2))}

    def train_fn(params, batch, key):
        return jax.tree.map(lambda p: p - 0.01, params)

    pcfg = PopulationConfig(
        mode="fixed", n_fixed=n_fixed, n_mules=n_mules, gamma=0.5,
        freshness=FreshnessConfig(init_threshold=1e9, warmup=10**6))
    state = init_population(jax.random.PRNGKey(0), init_model, pcfg)
    fid = jnp.array([0, 1, 2, 3, 0, 1, -1, 3], jnp.int32)
    exch = jnp.array([True, True, True, True, True, False, True, True])
    info = {"fixed_id": fid, "exchange": exch}
    fixed_batches = jnp.zeros((n_fixed, 2))
    key = jax.random.PRNGKey(7)

    ref = population_step(dict(state), info,
                          {"fixed": fixed_batches, "mule": None},
                          train_fn, pcfg, key)
    dcfg = DistributedConfig(pop=pcfg)
    co = {"fixed_id": np.asarray(fid)[None], "exchange": np.asarray(exch)[None]}
    final, _ = run_population_distributed_loop(
        to_distributed_state(state, dcfg), co,
        {"fixed": fixed_batches[None], "mule": None},
        train_fn, dcfg, mesh, key)
    for a, b in zip(jax.tree.leaves(final["fixed_models"]),
                    jax.tree.leaves(ref["fixed_models"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(jax.tree.leaves(final["mule_models"]),
                    jax.tree.leaves(ref["mule_models"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(final["mule_ts"]),
                                  np.asarray(ref["mule_ts"]))
