"""encounter_mix kernel: interpret-mode vs oracle + semantic properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.gossip import (encounter_matrix, flatten_population,
                                    unflatten_population)
from repro.core.aggregation import masked_group_mean
from repro.kernels.encounter_mix.kernel import encounter_mix_pallas
from repro.kernels.encounter_mix.ref import encounter_mix_reference


def _setup(m, d, seed=0, n_areas=2, p_active=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    pos = jax.random.uniform(ks[0], (m, 2))
    area = jax.random.randint(ks[1], (m,), 0, n_areas)
    w = jax.random.normal(ks[2], (m, d))
    active = (jax.random.uniform(ks[3], (m,)) < p_active)
    return pos, area, active, w


@pytest.mark.parametrize("m,d,block_m,block_d", [
    (20, 256, 8, 128),          # several row blocks, one d block
    (33, 130, 16, 128),         # ragged M and D (padding on both axes)
    (64, 1024, 64, 256),        # several d blocks
    (7, 5, 8, 128),             # smaller than one tile
])
@pytest.mark.parametrize("p_active", [1.0, 0.6])
def test_pallas_matches_ref(m, d, block_m, block_d, p_active):
    pos, area, active, w = _setup(m, d, p_active=p_active)
    ref, ref_mass = encounter_mix_reference(pos, area, active, w,
                                            radius=0.3)
    out, mass = encounter_mix_pallas(pos, area, active, w, radius=0.3,
                                     block_m=block_m, block_d=block_d,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(mass), np.asarray(ref_mass))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_ref_matches_dense_group_mean():
    """The fused op computes the same neighbor mean as the retired dense
    path (encounter matrix + per-leaf masked_group_mean), to float
    tolerance — it normalizes after the matmul instead of before."""
    pos, area, active, _ = _setup(24, 0, seed=3, p_active=0.7)
    models = {"a": jax.random.normal(jax.random.PRNGKey(5), (24, 3, 4)),
              "b": jax.random.normal(jax.random.PRNGKey(6), (24, 7))}
    enc = encounter_matrix(pos, area, 0.3, active).astype(jnp.float32)
    dense, dense_mass = masked_group_mean(models, enc)
    flat, spec = flatten_population(models)
    mixed, mass = encounter_mix_reference(pos, area, active, flat,
                                          radius=0.3)
    fused = unflatten_population(mixed, spec)
    np.testing.assert_array_equal(np.asarray(mass), np.asarray(dense_mass))
    for k in models:
        np.testing.assert_allclose(np.asarray(fused[k]),
                                   np.asarray(dense[k]), atol=1e-5)


def test_isolated_rows_are_zero_with_zero_mass():
    """No peer in radius/area (or inactive) -> zero mix row, zero mass."""
    pos = jnp.array([[0.0, 0.0], [0.05, 0.0], [0.9, 0.9], [0.0, 0.01]])
    area = jnp.array([0, 0, 0, 1])           # row 3: same spot, other area
    active = jnp.array([True, True, True, True])
    w = jnp.ones((4, 8))
    out, mass = encounter_mix_reference(pos, area, active, w, radius=0.15)
    np.testing.assert_array_equal(np.asarray(mass), [1, 1, 0, 0])
    assert np.all(np.asarray(out)[2:] == 0)
    # switching a peer off removes it from both sides
    out2, mass2 = encounter_mix_reference(
        pos, area, jnp.array([True, False, True, True]), w, radius=0.15)
    np.testing.assert_array_equal(np.asarray(mass2), [0, 0, 0, 0])
    assert np.all(np.asarray(out2) == 0)


def test_active_none_equals_all_ones():
    pos, area, active, w = _setup(16, 32, seed=9)
    a, am = encounter_mix_reference(pos, area, None, w, radius=0.3)
    b, bm = encounter_mix_reference(pos, area, jnp.ones((16,), bool), w,
                                    radius=0.3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(am), np.asarray(bm))
