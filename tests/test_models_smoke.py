"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import sgd


def _batch_for(cfg, b, s, key):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : s - cfg.vision_tokens]
        batch["vision_embed"] = 0.1 * jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["audio_embed"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))

    logits, aux = model.forward(params, batch)
    exp_s = s if cfg.family != "vlm" else s
    assert logits.shape == (b, exp_s, cfg.vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one real train step
    opt = sgd(0.01)
    opt_state = opt.init(params)
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert bool(jnp.isfinite(loss))
    new_params, _ = opt.update(params, grads, opt_state)
    moved = sum(float(jnp.sum(jnp.abs(a - b_))) for a, b_ in
                zip(jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 2
    cache = model.init_cache(b, 16)
    if cfg.family == "audio":
        ae = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                     (b, cfg.encoder_seq, cfg.d_model))
        cache = model.prefill_cross_kv(params, ae, cache)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache must actually change
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(cache2), jax.tree.leaves(cache)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected, (got, expected)
    assert cfg.source, "config must cite its source"


def test_moe_config_details():
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_experts, q.top_k) == (128, 8)
    g = get_config("granite-moe-1b-a400m")
    assert (g.n_experts, g.top_k) == (32, 8)
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64


def test_unroll_matches_scan():
    cfg = dataclasses.replace(get_smoke_config("stablelm-1.6b"), dtype="float32")
    batch = _batch_for(cfg, 2, 16, jax.random.PRNGKey(1))
    m_scan = build_model(cfg)
    m_unroll = build_model(cfg, unroll=True)
    params = m_scan.init(jax.random.PRNGKey(0))
    l1, _ = m_scan.forward(params, batch)
    l2, _ = m_unroll.forward(params, batch)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4
