"""Associative median/MAD sketch vs the exact ring-buffer statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.freshness import (FreshnessConfig, age_histogram,
                                  init_freshness_sketch, sketch_median_mad,
                                  sketch_push_and_update)


def _middle_bracket(vals):
    """The two order statistics bracketing the 0.5 quantile."""
    s = np.sort(vals)
    n = len(s)
    return s[max(n - 1, 0) // 2], s[n // 2]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_sketch_bracketed_on_ring_histories(seed):
    """On ring-sized (sparse) histories the sketch median/MAD land within
    one bin of the order statistics bracketing the 0.5 quantile — the
    estimator's honest guarantee (numpy's midpoint convention can sit
    anywhere inside the middle gap, so exact equality is not it)."""
    cfg = FreshnessConfig(sketch_bins=256, sketch_max_age=128.0)
    width = cfg.sketch_max_age / cfg.sketch_bins
    rng = np.random.default_rng(seed)
    # a ring-buffer-like history per device: ages in-range, some rows short
    f, k = 6, 16
    ages = rng.uniform(0.0, 100.0, size=(f, k)).astype(np.float32)
    valid = rng.uniform(size=(f, k)) < 0.8
    valid[:, 0] = True                        # at least one receipt per row
    hist = age_histogram(jnp.asarray(ages), jnp.asarray(valid, jnp.float32),
                         cfg)
    med, mad = sketch_median_mad(hist, cfg)
    for i in range(f):
        vals = ages[i][valid[i]]
        lo, hi = _middle_bracket(vals)
        assert lo - width - 1e-5 <= float(med[i]) <= hi + width + 1e-5, \
            (i, float(med[i]), lo, hi)
        # MAD bracket on distances from the sketch's own median (bin
        # centers add up to half a width each side)
        dlo, dhi = _middle_bracket(np.abs(vals - float(med[i])))
        assert dlo - 1.5 * width - 1e-5 <= float(mad[i]) \
            <= dhi + 1.5 * width + 1e-5, (i, float(mad[i]), dlo, dhi)


@pytest.mark.parametrize("seed", [0, 1])
def test_sketch_matches_exact_on_dense_histories(seed):
    """With many receipts the middle gap vanishes and the sketch agrees
    with jnp.median / exact MAD to a couple of bin widths."""
    cfg = FreshnessConfig(sketch_bins=256, sketch_max_age=128.0)
    width = cfg.sketch_max_age / cfg.sketch_bins
    rng = np.random.default_rng(100 + seed)
    f, k = 4, 4096
    ages = rng.uniform(0.0, 120.0, size=(f, k)).astype(np.float32)
    hist = age_histogram(jnp.asarray(ages), jnp.ones((f, k), jnp.float32),
                         cfg)
    med, mad = sketch_median_mad(hist, cfg)
    for i in range(f):
        em = float(jnp.median(jnp.asarray(ages[i])))
        ea = float(jnp.median(jnp.abs(jnp.asarray(ages[i]) - em)))
        assert abs(float(med[i]) - em) <= 2 * width, (float(med[i]), em)
        assert abs(float(mad[i]) - ea) <= 2 * width, (float(mad[i]), ea)


def test_sketch_histogram_is_associative():
    """Shard contributions merge by plain addition: hist(A ∪ B) ==
    hist(A) + hist(B) — the property that lets the engine psum them."""
    cfg = FreshnessConfig(sketch_bins=64, sketch_max_age=64.0)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0, 60, size=(4, 8)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 60, size=(4, 8)).astype(np.float32))
    ones = jnp.ones((4, 8))
    merged = age_histogram(jnp.concatenate([a, b], axis=1),
                           jnp.ones((4, 16)), cfg)
    parts = age_histogram(a, ones, cfg) + age_histogram(b, ones, cfg)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(parts))


def test_sketch_push_threshold_formula():
    """T' = (1-a) T + a (med + beta * mad) with the sketch's med/mad."""
    cfg = FreshnessConfig(alpha=0.25, beta=1.5, history=1000,
                          init_threshold=10.0, sketch_bins=128,
                          sketch_max_age=64.0)
    state = init_freshness_sketch(2, cfg)
    ages = jnp.asarray([[4.0, 8.0, 12.0]])
    step_hist = age_histogram(jnp.broadcast_to(ages, (2, 3)),
                              jnp.asarray([[1.0] * 3, [0.0] * 3]), cfg)
    out = sketch_push_and_update(state, step_hist,
                                 jnp.asarray([3.0, 0.0]), cfg)
    med, mad = sketch_median_mad(out["hist"], cfg)
    want = (1 - cfg.alpha) * 10.0 + cfg.alpha * (float(med[0])
                                                 + cfg.beta * float(mad[0]))
    np.testing.assert_allclose(float(out["threshold"][0]), want, rtol=1e-5)
    # device 1 received nothing: threshold must not move
    np.testing.assert_allclose(float(out["threshold"][1]), 10.0)
    assert int(out["count"][0]) == 3 and int(out["count"][1]) == 0


def test_sketch_mass_capped_at_history_depth():
    """Resident mass stays <= K, emulating the ring's last-K window."""
    cfg = FreshnessConfig(history=8, sketch_bins=32, sketch_max_age=32.0)
    state = init_freshness_sketch(1, cfg)
    for t in range(5):
        ages = jnp.asarray([[float(t), float(t) + 1.0, float(t) + 2.0]])
        step_hist = age_histogram(ages, jnp.ones((1, 3)), cfg)
        state = sketch_push_and_update(state, step_hist,
                                       jnp.asarray([3.0]), cfg)
    total = float(jnp.sum(state["hist"]))
    assert total <= cfg.history + 1e-4, total
    assert int(state["count"][0]) == 15      # receipts keep counting
