"""Shared test config.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
single real CPU device. Multi-device tests spawn subprocesses with
``--xla_force_host_platform_device_count`` themselves.
"""
import os
import subprocess
import sys

import pytest


def linear_population_setup(mode="mobile", seed=0, n_fixed=4, n_mules=6,
                            n_steps=18, **fresh_kw):
    """Tiny linear-regression population: fast to compile, exact numerics.

    The shared workload of the engine parity suites (``test_sweep``,
    ``test_distributed_engine``; ``test_distributed``'s subprocess prelude
    keeps an inline copy by necessity). Returns
    ``(pop, colocation, batch_fn, train_fn, pcfg)``.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.freshness import FreshnessConfig
    from repro.core.population import PopulationConfig, init_population
    from repro.scenarios import walk_colocation

    n = n_fixed if mode == "fixed" else n_mules
    X = jax.random.normal(jax.random.PRNGKey(50 + seed), (n, 12, 5))
    Y = jax.random.normal(jax.random.PRNGKey(60 + seed), (n, 12))

    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(key, t):
        idx = jax.random.randint(key, (n, 4), 0, X.shape[1])
        b = (jnp.take_along_axis(X, idx[:, :, None], 1),
             jnp.take_along_axis(Y, idx, 1))
        return ({"fixed": b, "mule": None} if mode == "fixed"
                else {"fixed": None, "mule": b})

    pcfg = PopulationConfig(mode=mode, n_fixed=n_fixed, n_mules=n_mules,
                            freshness=FreshnessConfig(**fresh_kw))
    pop = init_population(jax.random.PRNGKey(seed),
                          lambda k: {"w": jax.random.normal(k, (5,))}, pcfg)
    co = walk_colocation(seed, n_mules, n_steps)
    return pop, co, batch_fn, train_fn, pcfg


def assert_trees_bitwise(a, b, what="engines diverged"):
    """Leaf-for-leaf exact equality of two pytrees."""
    import jax
    import numpy as np
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), what


def run_with_devices(code: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def multi_device_runner():
    return run_with_devices
