"""Shared test config.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
single real CPU device. Multi-device tests spawn subprocesses with
``--xla_force_host_platform_device_count`` themselves.
"""
import os
import subprocess
import sys

import pytest


def run_with_devices(code: str, n_devices: int = 8) -> str:
    """Run a python snippet in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def multi_device_runner():
    return run_with_devices
