"""Property-based harness over the whole scenario registry.

Every scenario in ``SCENARIOS`` — present and future — must produce engine-
consumable colocation tensors for any (seed, n_mules, n_steps): valid space
ids, [T, M] shapes, boolean churn masks that never switch the whole
population off, and builds that are deterministic per seed. Runs under real
``hypothesis`` in CI and under the fixed-seed fallback sweep
(``repro.testing.hypo``) in the tier-1 container.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.mobility import (duty_cycle_mask, flash_churn_mask,
                            markov_churn_mask)
from repro.scenarios import SCENARIOS, get_scenario, list_scenarios


def _check_colocation(name, spec, co, n_mules, n_steps):
    fid = np.asarray(co["fixed_id"])
    exch = np.asarray(co["exchange"])
    assert fid.shape == (n_steps, n_mules), f"{name}: fixed_id shape"
    assert exch.shape == (n_steps, n_mules), f"{name}: exchange shape"
    assert exch.dtype == bool, f"{name}: exchange dtype"
    # colocation values are valid space ids: -1 (corridor) .. n_fixed-1
    assert fid.min() >= -1, f"{name}: fixed_id below -1"
    assert fid.max() < spec.n_fixed, \
        f"{name}: fixed_id {fid.max()} >= n_fixed {spec.n_fixed}"
    # an exchange needs a co-location to complete
    assert not (exch & (fid < 0)).any(), f"{name}: exchange without visit"
    if "pos" in co:
        assert np.asarray(co["pos"]).shape == (n_steps, n_mules, 2), \
            f"{name}: pos shape"
    if "area" in co:
        area = np.asarray(co["area"])
        assert area.shape in ((n_mules,), (n_steps, n_mules)), \
            f"{name}: area shape {area.shape}"
    act = np.asarray(co.get("active", np.ones(fid.shape, bool)))
    assert act.shape == (n_steps, n_mules), f"{name}: active shape"
    assert act.dtype == bool, f"{name}: active dtype"
    assert act.any(axis=1).all(), f"{name}: step with zero active mules"
    if spec.churn is not None:
        assert "active" in co, f"{name}: ChurnSpec but no active mask"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_mules=st.integers(min_value=2, max_value=16),
       n_steps=st.integers(min_value=2, max_value=96))
def test_every_scenario_builds_valid_colocation(seed, n_mules, n_steps):
    for name in list_scenarios():
        spec = SCENARIOS[name]
        co = spec.colocation(seed, n_mules, n_steps)
        _check_colocation(name, spec, co, n_mules, n_steps)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_mules=st.integers(min_value=2, max_value=12),
       n_steps=st.integers(min_value=8, max_value=64))
def test_every_scenario_is_deterministic_per_seed(seed, n_mules, n_steps):
    for name in list_scenarios():
        a = SCENARIOS[name].colocation(seed, n_mules, n_steps)
        b = SCENARIOS[name].colocation(seed, n_mules, n_steps)
        assert sorted(a) == sorted(b), f"{name}: key set varies"
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), \
                f"{name}: {k} differs across same-seed builds"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       n_mules=st.integers(min_value=1, max_value=12),
       n_steps=st.integers(min_value=1, max_value=80))
def test_mask_generators_shapes_and_liveness(seed, n_mules, n_steps):
    """The raw generators honour the registry's mask contract directly."""
    for gen in (markov_churn_mask, flash_churn_mask, duty_cycle_mask):
        m = gen(seed, n_steps, n_mules)
        assert m.shape == (n_steps, n_mules)
        assert m.dtype == bool
        assert m.any(axis=1).all(), f"{gen.__name__}: dead step"
        assert np.array_equal(m, gen(seed, n_steps, n_mules)), \
            f"{gen.__name__}: nondeterministic"


def test_churn_scenarios_actually_churn():
    """The new scenarios must exercise both directions of churn."""
    for name in ("commuter_churn", "event_crowd_flash"):
        act = np.asarray(SCENARIOS[name].colocation(0, 12, 200)["active"])
        assert act.any() and not act.all(), f"{name}: degenerate mask"
        flips = act[1:] != act[:-1]
        assert (act[1:] & ~act[:-1]).any(), f"{name}: nobody ever joins"
        assert (~act[1:] & act[:-1]).any(), f"{name}: nobody ever leaves"
        assert flips.any(axis=0).sum() >= act.shape[1] // 2, \
            f"{name}: churn touches too few mules"


def test_mixed_cadence_follows_space_specs():
    """Per-space exchange tempo: a dwell of d steps in space f completes
    exchanges exactly every spaces[f].exchange_steps steps."""
    spec = SCENARIOS["mixed_cadence"]
    cadence = np.array([sp.exchange_steps for sp in spec.spaces])
    co = spec.colocation(3, 10, 240)
    fid, exch = np.asarray(co["fixed_id"]), np.asarray(co["exchange"])
    dwell = np.zeros(10, np.int64)
    prev = -np.ones(10, np.int32)
    for t in range(fid.shape[0]):
        same = (fid[t] == prev) & (fid[t] >= 0)
        dwell = np.where(same, dwell + 1, np.where(fid[t] >= 0, 1, 0))
        want = (dwell > 0) & (dwell % cadence[np.clip(fid[t], 0, None)] == 0)
        np.testing.assert_array_equal(exch[t], want, f"step {t}")
        prev = fid[t]
    # heterogeneity is real: at least two different cadences fire
    fired = np.unique(cadence[fid[exch]])
    assert len(fired) >= 2, "only one exchange tempo ever exercised"


def test_multi_area_scenario_spans_three_areas():
    spec = SCENARIOS["multi_area_3city"]
    co = spec.colocation(0, 24, 400)
    fid = np.asarray(co["fixed_id"])
    areas = np.unique(fid[fid >= 0] // 4)
    assert set(areas.tolist()) == {0, 1, 2}, f"visited areas: {areas}"
    assert np.asarray(co["init_area"]).max() <= 2


def test_get_scenario_error_lists_available():
    """The lookup error must name every registered scenario (the old
    message was a bare unknown-name KeyError)."""
    with pytest.raises(ValueError) as exc:
        get_scenario("definitely_not_a_scenario")
    msg = str(exc.value)
    assert "definitely_not_a_scenario" in msg
    for name in list_scenarios():
        assert name in msg, f"error message omits {name!r}"


def test_registered_scenario_roundtrips():
    for name in list_scenarios():
        assert get_scenario(name).name == name
