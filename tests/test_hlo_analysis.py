"""Scan-aware HLO analyzer: validated against unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.dtypes import DTYPE_BYTES, UnknownDtypeError, dtype_bytes
from repro.launch.hlo_analysis import analyze_hlo


def _flops_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_scan_trip_count_multiplied():
    W = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    x0 = jax.ShapeDtypeStruct((8, 256), jnp.float32)

    def scanned(ws, x):
        def body(c, w):
            return jax.nn.relu(c @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    def unrolled(ws, x):
        for i in range(10):
            x = jax.nn.relu(x @ ws[i])
        return jnp.sum(x)

    grad_expected = 3 * 2 * 8 * 256 * 256 * 10   # fwd + 2 bwd matmuls x 10
    r_scan = _flops_of(jax.grad(scanned), W, x0)
    r_unroll = _flops_of(jax.grad(unrolled), W, x0)
    assert abs(r_scan.flops - grad_expected) / grad_expected < 0.05
    # unrolled may be slightly optimized but same ballpark
    assert abs(r_unroll.flops - grad_expected) / grad_expected < 0.15
    # bytes: scanned version should be within ~4x of unrolled (approximation)
    assert r_scan.bytes > 0 and r_unroll.bytes > 0


def test_single_matmul_exact():
    a = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    r = _flops_of(lambda a, b: a @ b, a, b)
    assert abs(r.flops - 2 * 1024 * 512 * 256) / (2 * 1024 * 512 * 256) < 1e-6


def test_nested_scan():
    W = jax.ShapeDtypeStruct((4, 3, 64, 64), jnp.float32)
    x0 = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def nested(ws, x):
        def outer(x, wouter):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, wouter)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    r = _flops_of(nested, W, x0)
    expected = 2 * 8 * 64 * 64 * 12
    assert abs(r.flops - expected) / expected < 0.05


def test_collectives_empty_on_single_device():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = _flops_of(lambda a: a @ a, a)
    assert r.coll_bytes == 0


_UNKNOWN_DTYPE_HLO = """\
HloModule bogus

ENTRY %main (p0: f9z99[32,32]) -> f9z99[32,32] {
  %p0 = f9z99[32,32]{1,0} parameter(0)
  ROOT %c = f9z99[32,32]{1,0} copy(%p0)
}
"""


def test_unknown_dtype_raises():
    """The silent ``.get(dtype, 4)`` fallback is gone: a dtype missing from
    the shared table must raise, naming the dtype — in both parsers."""
    from repro.launch.roofline import collective_bytes

    with pytest.raises(UnknownDtypeError, match="f9z99"):
        analyze_hlo(_UNKNOWN_DTYPE_HLO)
    bad_coll = ("ENTRY %e (p: f9z99[8]) -> f9z99[8] {\n"
                "  %p = f9z99[8]{0} parameter(0)\n"
                "  ROOT %ar = f9z99[8]{0} all-reduce(%p), replica_groups={}\n"
                "}\n")
    with pytest.raises(UnknownDtypeError, match="f9z99"):
        collective_bytes(bad_coll)


def test_unknown_dtype_collected():
    """``collect`` mode records unknowns (costed f32) instead of raising."""
    seen = set()
    assert dtype_bytes("f9z99", collect=seen) == 4
    assert dtype_bytes("f32", collect=seen) == 4
    assert seen == {"f9z99"}


def test_shared_dtype_table_is_single_source():
    """Both analyzers price shapes through the one shared table."""
    import repro.launch.hlo_analysis as ha
    import repro.launch.roofline as rl

    assert not hasattr(ha, "_DTYPE_BYTES")
    assert not hasattr(rl, "_DTYPE_BYTES")
    assert ha._shape_bytes("bf16[4,8]") == 4 * 8 * DTYPE_BYTES["bf16"]
    assert rl._shape_bytes("bf16", "4,8") == 4 * 8 * DTYPE_BYTES["bf16"]
