"""Scan-aware HLO analyzer: validated against unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def _flops_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_scan_trip_count_multiplied():
    W = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    x0 = jax.ShapeDtypeStruct((8, 256), jnp.float32)

    def scanned(ws, x):
        def body(c, w):
            return jax.nn.relu(c @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    def unrolled(ws, x):
        for i in range(10):
            x = jax.nn.relu(x @ ws[i])
        return jnp.sum(x)

    grad_expected = 3 * 2 * 8 * 256 * 256 * 10   # fwd + 2 bwd matmuls x 10
    r_scan = _flops_of(jax.grad(scanned), W, x0)
    r_unroll = _flops_of(jax.grad(unrolled), W, x0)
    assert abs(r_scan.flops - grad_expected) / grad_expected < 0.05
    # unrolled may be slightly optimized but same ballpark
    assert abs(r_unroll.flops - grad_expected) / grad_expected < 0.15
    # bytes: scanned version should be within ~4x of unrolled (approximation)
    assert r_scan.bytes > 0 and r_unroll.bytes > 0


def test_single_matmul_exact():
    a = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    r = _flops_of(lambda a, b: a @ b, a, b)
    assert abs(r.flops - 2 * 1024 * 512 * 256) / (2 * 1024 * 512 * 256) < 1e-6


def test_nested_scan():
    W = jax.ShapeDtypeStruct((4, 3, 64, 64), jnp.float32)
    x0 = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def nested(ws, x):
        def outer(x, wouter):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, wouter)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    r = _flops_of(nested, W, x0)
    expected = 2 * 8 * 64 * 64 * 12
    assert abs(r.flops - expected) / expected < 0.05


def test_collectives_empty_on_single_device():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    r = _flops_of(lambda a: a @ a, a)
    assert r.coll_bytes == 0
