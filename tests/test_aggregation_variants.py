"""Swappable aggregation (paper Sec 3.1/5): prox damping, quality weights."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: fixed-seed fallback sweep
    from repro.testing.hypo import given, settings, strategies as st

from repro.core.aggregation import pairwise_mix, prox_mix, quality_weights
from repro.core.freshness import FreshnessConfig
from repro.core.population import PopulationConfig, init_population, population_step


def test_prox_mix_damps_toward_local():
    local = {"w": jnp.zeros(4)}
    incoming = {"w": jnp.ones(4)}
    plain = pairwise_mix(local, incoming, 0.5)["w"]
    prox = prox_mix(local, incoming, 0.5, mu=0.25)["w"]
    assert float(prox[0]) < float(plain[0])
    np.testing.assert_allclose(np.asarray(prox), 0.5 / 1.25)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), temp=st.floats(0.1, 5.0))
def test_quality_weights_order(seed, temp):
    losses = jax.random.uniform(jax.random.PRNGKey(seed), (6,)) * 3
    w = quality_weights(losses, temperature=temp)
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-5)
    order_l = np.argsort(np.asarray(losses))
    order_w = np.argsort(-np.asarray(w))
    assert (order_l == order_w).all()   # lower loss -> higher weight


def test_population_prox_matches_effective_gamma():
    def init_model(k):
        return {"w": jax.random.normal(k, (3,))}

    common = dict(mode="fixed", n_fixed=2, n_mules=1,
                  freshness=FreshnessConfig(warmup=10, init_threshold=1e9))
    cfg_prox = PopulationConfig(gamma=0.5, aggregation="prox", prox_mu=0.25,
                                **common)
    cfg_eff = PopulationConfig(gamma=0.4, **common)   # 0.5 / 1.25
    s1 = init_population(jax.random.PRNGKey(0), init_model, cfg_prox)
    s2 = init_population(jax.random.PRNGKey(0), init_model, cfg_eff)
    info = {"fixed_id": jnp.array([0], jnp.int32), "exchange": jnp.array([True])}
    batches = {"fixed": jnp.zeros((2, 1)), "mule": None}
    train = lambda p, b, k: p
    o1 = population_step(s1, info, batches, train, cfg_prox, jax.random.PRNGKey(1))
    o2 = population_step(s2, info, batches, train, cfg_eff, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(o1["fixed_models"]["w"]),
                               np.asarray(o2["fixed_models"]["w"]), rtol=1e-6)
