"""Blockwise GQA flash attention as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
- grid = (batch*heads, n_q_blocks, n_k_blocks); the last grid axis is
  sequential on TPU, so the running-softmax state (m, l, acc) lives in VMEM
  scratch carried across k-block iterations.
- MXU-aligned blocks (block_q × head_dim and block_k × head_dim tiles,
  multiples of 128 recommended); fp32 accumulation, bf16 operands.
- causal + sliding-window handled by skipping whole k-blocks with ``pl.when``
  (a real compute skip on TPU, unlike a mask) and an in-block iota mask for
  the diagonal/band edges.
- GQA without materializing repeated KV heads: the k/v BlockSpec index_map
  divides the head index by the group size.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_k: int, seq_q: int, seq_k: int,
                 causal: bool, window: Optional[int]):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_off = seq_k - seq_q  # right-aligned query positions
    q_start = qi * block_q + q_off
    k_start = kj * block_k

    # whole-block band check on grid indices -> pl.when compute skip
    needed = None
    if causal:
        needed = k_start < q_start + block_q
    if window is not None:
        in_band = k_start + block_k > q_start - window
        needed = in_band if needed is None else jnp.logical_and(needed, in_band)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)                  # [bk, d]
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        valid = kpos < seq_k
        if causal:
            valid &= kpos <= qpos
        if window is not None:
            valid &= kpos > qpos - window
        sc = jnp.where(valid, sc, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
        p = jnp.exp(sc - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if needed is None:
        _compute()
    else:
        pl.when(needed)(_compute)

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "scale", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           scale: Optional[float] = None,
                           interpret: bool = True):
    """q: [B,S,H,D]; k,v: [B,Sk,KV,D] -> [B,S,H,D]."""
    b, s, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    nq = -(-s // block_q)
    nk = -(-sk // block_k)
    s_pad, sk_pad = nq * block_q, nk * block_k

    qr = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * n_kv, sk, d)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * n_kv, sk, d)
    if s_pad != s:
        qr = jnp.pad(qr, ((0, 0), (0, s_pad - s), (0, 0)))
    if sk_pad != sk:
        kr = jnp.pad(kr, ((0, 0), (0, sk_pad - sk), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, sk_pad - sk), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=s, seq_k=sk, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, kj, g=g: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :s].reshape(b, h, s, d)
    return jnp.moveaxis(out, 1, 2)
