"""Dispatching wrapper for blockwise GQA attention.

``backend``:
- ``"ref"``     — chunked pure-jnp flash (the CPU / dry-run compile path).
- ``"pallas"``  — the TPU kernel (interpret=False; real hardware).
- ``"interpret"`` — the TPU kernel executed by the Pallas interpreter on CPU
  (correctness validation in this container).
- ``"auto"``    — pallas on TPU backends, ref elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_reference, mha_reference  # noqa: F401


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    scale: Optional[float] = None, backend: str = "ref"):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return flash_reference(q, k, v, causal=causal, window=window,
                               block_q=max(block_q, 256), block_k=max(block_k, 256),
                               scale=scale)
    if backend in ("pallas", "interpret"):
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, block_q=block_q,
            block_k=block_k, scale=scale, interpret=(backend == "interpret"))
    raise ValueError(f"unknown backend {backend!r}")
