"""Pure-jnp oracles for blockwise GQA attention.

Two references:

- ``mha_reference`` — direct softmax(QK^T)V with full score materialization.
  The ground-truth oracle for kernel tests; only safe at small S.
- ``flash_reference`` — chunked running-softmax (flash-style) in pure jnp,
  memory-bounded; the production CPU/compile path used by the model zoo and
  the dry-run. Supports causal masking, sliding windows and GQA without
  materializing [S, S] scores or repeated KV heads.

Shapes: q [B, S, H, D]; k, v [B, S, KV, D] with H % KV == 0.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group(q, n_kv):
    """[B,S,H,D] -> [B,S,KV,G,D]."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def mha_reference(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None):
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = _group(q, n_kv).astype(jnp.float32)
    scores = jnp.einsum("bikgd,bjkd->bkgij", qg * scale, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned q positions
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _band_range(qi, block_q, block_k, window, sk, q_off):
    """Static-length contiguous KV range covering the sliding-window band."""
    span = ((window + block_k - 1) // block_k) * block_k + block_q
    span = min(span, ((sk + block_k - 1) // block_k) * block_k)
    start = jnp.clip(qi * block_q + q_off + block_q - span, 0, max(sk - span, 0))
    return start, span


def _fwd_impl(q, k, v, causal, window, block_q, block_k, scale):
    """Returns (out [B,S,H,D], m, l stats [B,nq*Bq,KV,G])."""
    b, s, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    nq = -(-s // block_q)
    pad_q = nq * block_q - s
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    qg = _group(qp, n_kv).reshape(b, nq, block_q, n_kv, g, d)
    q_off = sk - s

    nk = -(-sk // block_k)
    pad_k = nk * block_k - sk
    k_pad = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    v_pad = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    def one_q_block(qi, q_blk):
        q32 = q_blk.astype(jnp.float32) * scale
        qpos = qi * block_q + jnp.arange(block_q) + q_off

        if window is not None:
            start, span = _band_range(qi, block_q, block_k, window, sk, q_off)
            k_rng = jax.lax.dynamic_slice_in_dim(k_pad, start, span, axis=1)
            v_rng = jax.lax.dynamic_slice_in_dim(v_pad, start, span, axis=1)
            kpos = start + jnp.arange(span)
            valid = (kpos[None, :] <= qpos[:, None]) \
                & (kpos[None, :] > qpos[:, None] - window) & (kpos < sk)[None, :]
            sc = jnp.einsum("bikgd,bjkd->bkgij", q32, k_rng.astype(jnp.float32))
            sc = jnp.where(valid[None, None, None], sc, NEG_INF)
            m = jnp.max(sc, axis=-1, keepdims=True)
            p = jnp.exp(sc - m)
            l = jnp.sum(p, axis=-1, keepdims=True)
            o = jnp.einsum("bkgij,bjkd->bikgd", p / jnp.maximum(l, 1e-30),
                           v_rng.astype(jnp.float32))
            ml = jnp.moveaxis(m[..., 0], -1, 1)      # [B, Bq, KV, G]
            ll = jnp.moveaxis(l[..., 0], -1, 1)
            return o, ml, ll

        def kv_step(carry, kj):
            m_prev, l_prev, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k_pad, kj * block_k, block_k, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v_pad, kj * block_k, block_k, axis=1)
            kpos = kj * block_k + jnp.arange(block_k)
            valid = (kpos < sk)[None, :] * jnp.ones((block_q, 1), bool)
            if causal:
                valid &= kpos[None, :] <= qpos[:, None]
            sc = jnp.einsum("bikgd,bjkd->bkgij", q32, k_blk.astype(jnp.float32))
            sc = jnp.where(valid[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
            p = jnp.exp(sc - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
            corr_b = jnp.moveaxis(corr[..., 0], -1, 1)[..., None]
            acc = acc * corr_b + jnp.moveaxis(
                jnp.einsum("bkgij,bjkd->bkgid", p, v_blk.astype(jnp.float32)), 3, 1)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, n_kv, g, block_q, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, block_q, 1), jnp.float32)
        acc0 = jnp.zeros((b, block_q, n_kv, g, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0), jnp.arange(nk))
        l_b = jnp.moveaxis(l[..., 0], -1, 1)[..., None]
        o = acc / jnp.maximum(l_b, 1e-30)
        return o, jnp.moveaxis(m[..., 0], -1, 1), jnp.moveaxis(l[..., 0], -1, 1)

    def scan_body(_, xs):
        return None, one_q_block(*xs)

    _, (out, ms, ls) = jax.lax.scan(scan_body, None,
                                    (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * block_q, h, d)
    ms = jnp.moveaxis(ms, 0, 1).reshape(b, nq * block_q, n_kv, g)
    ls = jnp.moveaxis(ls, 0, 1).reshape(b, nq * block_q, n_kv, g)
    return out[:, :s].astype(q.dtype), ms[:, :s], ls[:, :s]


def _bwd_impl(q, k, v, out, ms, ls, dout, causal, window, block_q, block_k, scale):
    """Flash-style two-pass backward; O(S·d) live memory, recomputes scores."""
    b, s, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    nq = -(-s // block_q)
    pad_q = nq * block_q - s
    q_off = sk - s
    nk = -(-sk // block_k)
    pad_k = nk * block_k - sk

    def padq(t):
        return jnp.pad(t, ((0, 0), (0, pad_q)) + ((0, 0),) * (t.ndim - 2)) if pad_q else t

    def padk(t):
        return jnp.pad(t, ((0, 0), (0, pad_k)) + ((0, 0),) * (t.ndim - 2)) if pad_k else t

    qg = _group(padq(q), n_kv).reshape(b, nq, block_q, n_kv, g, d).astype(jnp.float32)
    dog = _group(padq(dout.astype(jnp.float32)), n_kv).reshape(b, nq, block_q, n_kv, g, d)
    og = _group(padq(out.astype(jnp.float32)), n_kv).reshape(b, nq, block_q, n_kv, g, d)
    msr = padq(ms).reshape(b, nq, block_q, n_kv, g)
    lsr = padq(ls).reshape(b, nq, block_q, n_kv, g)
    delta = jnp.sum(dog * og, axis=-1)                        # [B,nq,Bq,KV,G]
    kr = padk(k).astype(jnp.float32)
    vr = padk(v).astype(jnp.float32)

    def scores(qi, kj_start, span_k):
        """Recompute normalized p for q block qi vs KV range. -> [B,KV,G,Bq,span]"""
        q_blk = qg[:, qi] * scale
        k_rng = jax.lax.dynamic_slice_in_dim(kr, kj_start, span_k, axis=1)
        qpos = qi * block_q + jnp.arange(block_q) + q_off
        kpos = kj_start + jnp.arange(span_k)
        valid = (kpos[None, :] <= qpos[:, None]) if causal else \
            jnp.ones((block_q, span_k), bool)
        if window is not None:
            valid &= kpos[None, :] > qpos[:, None] - window
        valid &= (kpos < sk)[None, :]
        sc = jnp.einsum("bikgd,bjkd->bkgij", q_blk, k_rng)
        sc = jnp.where(valid[None, None, None], sc, NEG_INF)
        m_i = jnp.moveaxis(msr[:, qi], 1, -1)[..., None]      # [B,KV,G,Bq,1]
        l_i = jnp.moveaxis(lsr[:, qi], 1, -1)[..., None]
        p = jnp.exp(sc - m_i) / jnp.maximum(l_i, 1e-30)
        return p, k_rng, valid

    def ds_of(qi, p, v_rng):
        dP = jnp.einsum("bikgd,bjkd->bkgij", dog[:, qi], v_rng)
        dl = jnp.moveaxis(delta[:, qi], 1, -1)[..., None]     # [B,KV,G,Bq,1]
        return p * (dP - dl)

    # ---- pass 1: dQ per q block ------------------------------------------------
    def dq_block(qi):
        if window is not None:
            start, span = _band_range(qi, block_q, block_k, window, sk, q_off)
            p, k_rng, _ = scores(qi, start, span)
            v_rng = jax.lax.dynamic_slice_in_dim(vr, start, span, axis=1)
            dS = ds_of(qi, p, v_rng)
            return jnp.einsum("bkgij,bjkd->bikgd", dS, k_rng) * scale

        def kv_step(dq, kj):
            p, k_rng, _ = scores(qi, kj * block_k, block_k)
            v_rng = jax.lax.dynamic_slice_in_dim(vr, kj * block_k, block_k, axis=1)
            dS = ds_of(qi, p, v_rng)
            return dq + jnp.einsum("bkgij,bjkd->bikgd", dS, k_rng) * scale, None

        hi = min(qi + 1, nk) if causal and q_off == 0 else nk
        dq0 = jnp.zeros((b, block_q, n_kv, g, d), jnp.float32)
        dq, _ = jax.lax.scan(kv_step, dq0, jnp.arange(hi))
        return dq

    dq = jnp.stack([dq_block(qi) for qi in range(nq)], axis=1)
    dq = dq.reshape(b, nq * block_q, h, d)[:, :s].astype(q.dtype)

    # ---- pass 2: dK, dV per kv block --------------------------------------------
    def dkv_block(kj):
        lo = kj if (causal and q_off == 0 and block_q == block_k) else 0
        if window is not None:
            # q blocks whose band includes kv block kj
            lo = max(0, (kj * block_k - block_q - q_off) // block_q)
        def q_step(carry, qi):
            dk, dv = carry
            p, _, _ = scores(qi, kj * block_k, block_k)
            v_rng = jax.lax.dynamic_slice_in_dim(vr, kj * block_k, block_k, axis=1)
            dS = ds_of(qi, p, v_rng)
            dv = dv + jnp.einsum("bkgij,bikgd->bjkd", p, dog[:, qi])
            dk = dk + jnp.einsum("bkgij,bikgd->bjkd", dS, qg[:, qi]) * scale
            return (dk, dv), None

        z = jnp.zeros((b, block_k, n_kv, d), jnp.float32)
        hi = nq
        if window is not None:
            hi = min(nq, (kj * block_k + block_k + window) // block_q + 1)
        (dk, dv), _ = jax.lax.scan(q_step, (z, z), jnp.arange(lo, hi))
        return dk, dv

    dks, dvs = zip(*[dkv_block(kj) for kj in range(nk)])
    dk = jnp.concatenate(dks, axis=1)[:, :sk].astype(k.dtype)
    dv = jnp.concatenate(dvs, axis=1)[:, :sk].astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_core(q, k, v, cfgt):
    out, _, _ = _fwd_impl(q, k, v, *cfgt)
    return out


def _flash_core_fwd(q, k, v, cfgt):
    out, ms, ls = _fwd_impl(q, k, v, *cfgt)
    return out, (q, k, v, out, ms, ls)


def _flash_core_bwd(cfgt, res, dout):
    q, k, v, out, ms, ls = res
    return _bwd_impl(q, k, v, out, ms, ls, dout, *cfgt)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "scale"))
def flash_reference(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    scale: Optional[float] = None):
    """Chunked attention with running softmax; O(S·block) live memory in both
    the forward AND the backward pass (custom VJP with flash-style two-pass
    recompute — differentiating naively through the KV scan would store
    O(S^2) residuals).

    For ``window`` set, each query block only visits the contiguous KV range
    covering its band — a real FLOP reduction (block-banded), not just a mask.
    """
    b, s, h, d = q.shape
    _, sk, _, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    return _flash_core(q, k, v, (causal, window, block_q, block_k, scale))
