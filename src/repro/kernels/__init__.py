"""Pallas TPU kernels for the compute hot spots, each with a pure-jnp oracle.

- ``mule_agg``        — fused dwell-weighted population aggregation (the ML
                        Mule aggregation step at population scale; memory-bound).
- ``encounter_mix``   — fused peer-encounter neighbor mix (gossip baselines):
                        one flat matmul instead of per-leaf group means on
                        every backend; the tiled Pallas path additionally
                        never materializes the [M, M] encounter matrix
                        (the jnp oracle, the exact default, still does).
- ``flash_attention`` — blockwise causal/windowed GQA attention (train/prefill
                        hot spot of the assigned transformer archs).
- ``ssm_scan``        — chunked Mamba2/SSD selective-state-space scan (zamba2).

Layout per kernel: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd dispatching wrapper), ``ref.py`` (pure-jnp oracle). Kernels target TPU
(MXU-aligned blocks, VMEM tiling) and are validated on CPU via interpret=True.
"""
