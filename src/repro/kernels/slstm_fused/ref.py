"""Oracle for the fused sLSTM recurrence.

Inputs are the gate pre-activations (the parallel x @ W_in part is computed
outside): z/i/f/o each [B, S, H, P], recurrent weights r [4, H, P, P].
Stabilized exponential gating per the xLSTM paper (Sec 3.1):

    m_t = max(logsig(f_pre) + m_{t-1}, i_pre)
    i = exp(i_pre - m_t); f = exp(logsig(f_pre) + m_{t-1} - m_t)
    c = f c + i tanh(z);  n = f n + i;  h = sigmoid(o) * c / max(n, eps)

Returns h over time [B, S, H, P] and the final (h, c, n, m) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_reference(pre: jnp.ndarray, r: jnp.ndarray, state=None):
    """pre: [B, S, 4, H, P] gate pre-activations (z,i,f,o); r: [4, H, P, P]."""
    b, s, _, h, p = pre.shape
    if state is None:
        z = jnp.zeros((b, h, p), jnp.float32)
        state = {"h": z, "c": z, "n": z, "m": jnp.full((b, h, p), -1e30)}

    def rec(w, hp):
        return jnp.einsum("bhp,hpq->bhq", hp, w)

    def step(st, pre_t):
        h_prev = st["h"]
        z_pre = pre_t[:, 0] + rec(r[0], h_prev)
        i_pre = pre_t[:, 1] + rec(r[1], h_prev)
        f_pre = pre_t[:, 2] + rec(r[2], h_prev)
        o_pre = pre_t[:, 3] + rec(r[3], h_prev)
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + st["m"], i_pre)
        i_act = jnp.exp(i_pre - m_new)
        f_act = jnp.exp(jax.nn.log_sigmoid(f_pre) + st["m"] - m_new)
        c = f_act * st["c"] + i_act * jnp.tanh(z_pre)
        n = f_act * st["n"] + i_act
        h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
        return {"h": h_new, "c": c, "n": n, "m": m_new}, h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre.astype(jnp.float32), 1, 0))
    return jnp.moveaxis(hs, 0, 1), state
