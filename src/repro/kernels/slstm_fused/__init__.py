from repro.kernels.slstm_fused.ops import slstm_scan  # noqa: F401
