"""Dispatching wrapper for the fused sLSTM recurrence."""
from __future__ import annotations

import jax

from repro.kernels.slstm_fused.ref import slstm_reference  # noqa: F401


def slstm_scan(pre, r, *, backend: str = "ref"):
    """pre [B,S,4,H,P]; r [4,H,P,P] -> h [B,S,H,P]."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return slstm_reference(pre, r)[0]
    from repro.kernels.slstm_fused.kernel import slstm_scan_pallas
    return slstm_scan_pallas(pre, r, interpret=(backend == "interpret"))
