"""Fused sLSTM recurrence as a Pallas TPU kernel.

Why a kernel: the sLSTM recurrence is inherently sequential; lowered as a
lax.scan, every one of S steps re-streams the recurrent weights
r [4, H, P, P] from HBM (measured 99 TiB/device for xlstm-350m at 32k —
the worst roofline row in EXPERIMENTS.md). TPU-native fix: a sequential
grid over time with

- r resident in VMEM for the whole sweep (the BlockSpec index_map is
  constant, so Pallas never re-copies it between grid steps);
- the (h, c, n, m) cell state living in VMEM scratch across steps;
- per-step HBM traffic = one [B, 4, H, P] gate slice in + one [B, H, P]
  output slice out.

Per-step traffic drops from ~(|r| + states) to ~9·B·H·P·4 bytes — a
measured ~60x reduction of the memory-roofline term (§Perf pair 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(pre_ref, r_ref, h_out_ref, h_scr, c_scr, n_scr, m_scr):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    pre = pre_ref[0].astype(jnp.float32)         # [B, 4, H, P]
    r = r_ref[...].astype(jnp.float32)           # [4, H, P, P]
    h_prev = h_scr[...]                          # [B, H, P]

    def rec(g):
        # [B,H,P] x [H,P,P] -> [H,B,P] -> [B,H,P]
        out = jax.lax.dot_general(
            h_prev, r[g], (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)
        return jnp.moveaxis(out, 0, 1)

    z_pre = pre[:, 0] + rec(0)
    i_pre = pre[:, 1] + rec(1)
    f_pre = pre[:, 2] + rec(2)
    o_pre = pre[:, 3] + rec(3)
    logf = jax.nn.log_sigmoid(f_pre)
    m_prev = m_scr[...]
    m_new = jnp.maximum(logf + m_prev, i_pre)
    i_act = jnp.exp(i_pre - m_new)
    f_act = jnp.exp(logf + m_prev - m_new)
    c = f_act * c_scr[...] + i_act * jnp.tanh(z_pre)
    n = f_act * n_scr[...] + i_act
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)

    h_scr[...] = h_new
    c_scr[...] = c
    n_scr[...] = n
    m_scr[...] = m_new
    h_out_ref[0] = h_new.astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_scan_pallas(pre, r, *, interpret: bool = True):
    """pre: [B, S, 4, H, P]; r: [4, H, P, P] -> h [B, S, H, P]."""
    b, s, four, h, p = pre.shape
    assert four == 4
    pre_t = jnp.moveaxis(pre, 1, 0)              # [S, B, 4, H, P]
    out = pl.pallas_call(
        _slstm_kernel,
        grid=(s,),
        in_specs=[
            pl.BlockSpec((1, b, 4, h, p), lambda t: (t, 0, 0, 0, 0)),
            pl.BlockSpec((4, h, p, p), lambda t: (0, 0, 0, 0)),  # VMEM-resident
        ],
        out_specs=pl.BlockSpec((1, b, h, p), lambda t: (t, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, b, h, p), pre.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, h, p), jnp.float32),
            pltpu.VMEM((b, h, p), jnp.float32),
            pltpu.VMEM((b, h, p), jnp.float32),
            pltpu.VMEM((b, h, p), jnp.float32),
        ],
        interpret=interpret,
    )(pre_t, r)
    return jnp.moveaxis(out, 0, 1)
