"""Oracle for the fused encounter-mix kernel — and the one block math.

``encounter_block`` is the *single* definition of the peer-encounter
partial update: distance test, area isolation, activity gating, and
self-exclusion of one (row-block x column-block) pair, returning the
unnormalized neighbor sums and per-row neighbor counts. Every engine path
composes it:

- single host: one call with the whole population as both blocks
  (``encounter_mix_reference``);
- distributed: one call per ring hop, the column block streamed around the
  mesh mule axis by ``ppermute`` (``repro.baselines.gossip``), partials
  accumulated blockwise;
- the Pallas kernel re-implements the same math tile by tile
  (``kernel.py``), pinned to this oracle by ``tests/test_kernels_encounter``.

Because a 1-shard ring *is* the reference call, the distributed engines are
bitwise-equal to single host on a 1-device mesh by construction (under the
engines' default ``enc_backend="ref"``; the Pallas path trades that for
tile throughput and is pinned to this oracle by tolerance instead).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def encounter_gate(pos_r: jnp.ndarray, area_r: jnp.ndarray,
                   act_r: Optional[jnp.ndarray], row0,
                   pos_v: jnp.ndarray, area_v: jnp.ndarray,
                   act_v: Optional[jnp.ndarray], col0
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pairwise distances + every non-distance encounter gate of one
    (row block x visiting block) pair.

    pos_r [R, 2], area_r [R], act_r [R] bool (None == all active), row0 the
    rows' global population offset; ``*_v``/``col0`` likewise for the
    visiting block. Returns (d2 [R, V], gate [R, V] bool) where ``gate``
    ANDs area isolation, both-sides activity, and self-exclusion — the
    single definition every consumer (mean mix, nearest-peer search,
    Pallas tiles) composes with its own radius test.
    """
    d2 = jnp.sum((pos_r[:, None] - pos_v[None, :]) ** 2, axis=-1)
    gate = area_r[:, None] == area_v[None, :]
    if act_r is not None:
        gate = gate & act_r[:, None]
    if act_v is not None:
        gate = gate & act_v[None, :]
    ridx = row0 + jnp.arange(pos_r.shape[0])
    cidx = col0 + jnp.arange(pos_v.shape[0])
    gate = gate & (ridx[:, None] != cidx[None, :])      # no self-encounter
    return d2, gate


def encounter_block(pos_r: jnp.ndarray, area_r: jnp.ndarray,
                    act_r: Optional[jnp.ndarray], row0,
                    pos_v: jnp.ndarray, area_v: jnp.ndarray,
                    act_v: Optional[jnp.ndarray], col0,
                    weights_v: jnp.ndarray, radius: float
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Partial encounter mix of a row block against a visiting column block.

    ``encounter_gate`` arguments plus weights_v [V, D], the visiting
    models flattened. Returns (acc [R, D] unnormalized neighbor sums,
    mass [R] counts).
    """
    d2, gate = encounter_gate(pos_r, area_r, act_r, row0,
                              pos_v, area_v, act_v, col0)
    e = ((d2 <= radius ** 2) & gate).astype(jnp.float32)
    return e @ weights_v, jnp.sum(e, axis=1)


def normalize_mix(acc: jnp.ndarray, mass: jnp.ndarray) -> jnp.ndarray:
    """Row-normalize accumulated neighbor sums (zero rows stay zero)."""
    return acc / jnp.maximum(mass, 1e-12)[:, None]


def encounter_mix_reference(pos: jnp.ndarray, area: jnp.ndarray,
                            active: Optional[jnp.ndarray],
                            weights: jnp.ndarray, *, radius: float
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """pos [M, 2] x area [M] x weights [M, D] -> (mixed [M, D], mass [M]).

    mixed[i] = mean of weights[j] over encountered peers j (same area,
    within ``radius``, both active, j != i); rows with no peer are zero and
    callers gate on ``mass``.
    """
    acc, mass = encounter_block(pos, area, active, 0, pos, area, active, 0,
                                weights, radius)
    return normalize_mix(acc, mass), mass
