"""Fused peer-encounter mix as a tiled Pallas TPU kernel.

The retired dense path built the full [M, M] encounter matrix and ran a
``masked_group_mean`` over every model leaf — one [M, M] normalization pass
plus one skinny matmul *per leaf*, O(M^2 * L) memory traffic on top of the
O(M^2 * D) MACs. Here the [M, M] matrix never exists: the grid walks
``(row block, d block)`` output tiles; geometry (x, y, area, active — a
tiny [4, M] strip) stays VMEM-resident across the whole grid, each step
recomputes the distance/area/activity test for one [block_m, M] strip in
registers, and a single [block_m, M] x [M, block_d] MXU matmul produces the
already-normalized mix tile. The per-row neighbor count (``mass``) falls
out of the same strip and is written once per row block.

Arithmetic intensity per weight element is ~block_m MACs — the same
streaming roofline shape as ``mule_agg`` — while the dense path's
per-leaf [M, M] reads disappear entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(g_ref, gr_ref, w_ref, o_ref, mass_ref, *, radius: float,
                block_m: int):
    i = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)          # [4, M]   resident
    gr = gr_ref[...].astype(jnp.float32)        # [4, block_m] this row block
    m_tot = g.shape[1]

    dx = gr[0][:, None] - g[0][None, :]         # [block_m, M]
    dy = gr[1][:, None] - g[1][None, :]
    d2 = dx * dx + dy * dy
    enc = (d2 <= radius * radius)
    enc &= gr[2][:, None] == g[2][None, :]      # area isolation
    enc &= (gr[3][:, None] > 0) & (g[3][None, :] > 0)   # both active
    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_m, m_tot), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_m, m_tot), 1)
    enc &= rows != cols                         # no self-encounter
    e = enc.astype(jnp.float32)
    mass = jnp.sum(e, axis=1)                   # [block_m]

    w = w_ref[...].astype(jnp.float32)          # [M, block_d] streamed
    acc = jax.lax.dot_general(e, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = (acc / jnp.maximum(mass, 1e-12)[:, None]).astype(o_ref.dtype)
    mass_ref[...] = mass[None, :].astype(mass_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radius", "block_m", "block_d",
                                             "interpret"))
def encounter_mix_pallas(pos: jnp.ndarray, area: jnp.ndarray,
                         active: jnp.ndarray, weights: jnp.ndarray, *,
                         radius: float = 0.15, block_m: int = 256,
                         block_d: int = 2048, interpret: bool = True):
    """pos [M, 2], area [M], active [M], weights [M, D] -> (mix [M, D],
    mass [M]) — the ``encounter_mix_reference`` contract, tiled."""
    m, d = weights.shape
    block_m = min(block_m, max(8, m))
    block_d = min(block_d, max(128, d))
    nm, nd = -(-m // block_m), -(-d // block_d)
    m_pad, d_pad = nm * block_m, nd * block_d

    geom = jnp.stack([pos[:, 0].astype(jnp.float32),
                      pos[:, 1].astype(jnp.float32),
                      area.astype(jnp.float32),
                      active.astype(jnp.float32)])            # [4, M]
    if m_pad != m:
        # padded lanes carry active=0, so they join no encounter
        geom = jnp.pad(geom, ((0, 0), (0, m_pad - m)))
        weights = jnp.pad(weights, ((0, m_pad - m), (0, 0)))
    if d_pad != d:
        weights = jnp.pad(weights, ((0, 0), (0, d_pad - d)))

    out, mass = pl.pallas_call(
        functools.partial(_mix_kernel, radius=radius, block_m=block_m),
        grid=(nm, nd),
        in_specs=[
            pl.BlockSpec((4, m_pad), lambda i, j: (0, 0)),      # resident
            pl.BlockSpec((4, block_m), lambda i, j: (0, i)),    # row block
            pl.BlockSpec((m_pad, block_d), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, d_pad), weights.dtype),
            jax.ShapeDtypeStruct((1, m_pad), jnp.float32),
        ],
        interpret=interpret,
    )(geom, geom, weights)
    return out[:m, :d], mass[0, :m]


def _hop_kernel(gv_ref, gr_ref, w_ref, acc_ref, mass_ref, *, radius: float):
    gv = gv_ref[...].astype(jnp.float32)        # [5, V]        resident
    gr = gr_ref[...].astype(jnp.float32)        # [5, block_m]  this row block

    dx = gr[0][:, None] - gv[0][None, :]        # [block_m, V]
    dy = gr[1][:, None] - gv[1][None, :]
    d2 = dx * dx + dy * dy
    enc = (d2 <= radius * radius)
    enc &= gr[2][:, None] == gv[2][None, :]     # area isolation
    enc &= (gr[3][:, None] > 0) & (gv[3][None, :] > 0)   # both active
    enc &= gr[4][:, None] != gv[4][None, :]     # global-id self exclusion
    e = enc.astype(jnp.float32)
    mass = jnp.sum(e, axis=1)                   # [block_m]

    w = w_ref[...].astype(jnp.float32)          # [V, block_d] streamed
    acc = jax.lax.dot_general(e, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc_ref[...] = acc.astype(acc_ref.dtype)
    mass_ref[...] = mass[None, :].astype(mass_ref.dtype)


@functools.partial(jax.jit, static_argnames=("radius", "block_m", "block_d",
                                             "interpret"))
def encounter_hop_pallas(pos_r, area_r, act_r, row0, pos_v, area_v, act_v,
                         col0, weights_v, *, radius: float = 0.15,
                         block_m: int = 256, block_d: int = 2048,
                         interpret: bool = True):
    """One ring hop of the mix, tiled: local rows [R] vs a visiting block
    [V] whose global rows start at ``col0`` — the ``encounter_block``
    contract ((acc [R, D], mass [R]), *unnormalized* partials that the
    ring accumulates across hops and normalizes once at the end).

    Geometry is a [5, ·] strip per side — x, y, area, active, plus a
    float32 global row id (``row0``/``col0`` + lane) so self-exclusion
    works across blocks; the visiting strip stays VMEM-resident while the
    grid walks (row block, d block) tiles of the visiting weights, exactly
    the ``encounter_mix_pallas`` streaming shape. ``row0``/``col0`` are
    traced (the ring derives them from ``axis_index``), so one compiled
    kernel serves every hop.
    """
    r = pos_r.shape[0]
    v, d = weights_v.shape
    block_m = min(block_m, max(8, r))
    block_d = min(block_d, max(128, d))
    nr, nd = -(-r // block_m), -(-d // block_d)
    r_pad, d_pad = nr * block_m, nd * block_d
    v_pad = max(8, v)

    def geom(pos, area, act, g0, n, n_pad):
        g = jnp.stack([pos[:, 0].astype(jnp.float32),
                       pos[:, 1].astype(jnp.float32),
                       area.astype(jnp.float32),
                       act.astype(jnp.float32),
                       g0 + jnp.arange(n, dtype=jnp.float32)])   # [5, n]
        if n_pad != n:
            # padded lanes carry active=0, so they join no encounter
            g = jnp.pad(g, ((0, 0), (0, n_pad - n)))
        return g

    geom_r = geom(pos_r, area_r, act_r, row0, r, r_pad)
    geom_v = geom(pos_v, area_v, act_v, col0, v, v_pad)
    if v_pad != v:
        weights_v = jnp.pad(weights_v, ((0, v_pad - v), (0, 0)))
    if d_pad != d:
        weights_v = jnp.pad(weights_v, ((0, 0), (0, d_pad - d)))

    acc, mass = pl.pallas_call(
        functools.partial(_hop_kernel, radius=radius),
        grid=(nr, nd),
        in_specs=[
            pl.BlockSpec((5, v_pad), lambda i, j: (0, 0)),      # resident
            pl.BlockSpec((5, block_m), lambda i, j: (0, i)),    # row block
            pl.BlockSpec((v_pad, block_d), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, r_pad), jnp.float32),
        ],
        interpret=interpret,
    )(geom_v, geom_r, weights_v)
    return acc[:r, :d], mass[0, :r]
