"""Dispatching wrapper for the fused peer-encounter mix.

``backend``:
- ``"ref"``       — the jnp oracle (engine default; exact, CPU-friendly).
- ``"pallas"``    — the tiled kernel, compiled (TPU) or interpreted per the
                    same autodetect/env override as ``mule_agg``.
- ``"interpret"`` — the tiled kernel, interpreter forced.
- ``"auto"``      — pallas on TPU, ref elsewhere.

``REPRO_PALLAS_INTERPRET`` overrides the interpret autodetect exactly like
``repro.kernels.mule_agg.ops``.

Tile sizes: ``block_m``/``block_d`` left as ``None`` consult the autotune
cache (``repro.launch.autotune.tuned_encounter_blocks`` — the measured
selection committed in ``benchmarks/BENCH_roofline.json``, nearest tuned
[M, D] shape) and fall back to the pre-tuning hand defaults (256, 2048)
without one. Explicit values always win; ``REPRO_TUNE_CACHE`` repoints
(or, empty, disables) the cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.encounter_mix.kernel import encounter_mix_pallas
from repro.kernels.encounter_mix.ref import (  # noqa: F401
    encounter_block, encounter_gate, encounter_mix_reference, normalize_mix)
from repro.kernels.mule_agg.ops import _env_interpret


def encounter_mix(pos: jnp.ndarray, area: jnp.ndarray,
                  active: Optional[jnp.ndarray], weights: jnp.ndarray, *,
                  radius: float = 0.15, backend: str = "ref",
                  block_m: int | None = None, block_d: int | None = None,
                  interpret: bool | None = None):
    """pos [M, 2] x area [M] x weights [M, D] -> (mix [M, D], mass [M])."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return encounter_mix_reference(pos, area, active, weights,
                                       radius=radius)
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown encounter_mix backend {backend!r}; "
                         "expected ref | pallas | interpret | auto")
    if interpret is None:
        interpret = _env_interpret()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "interpret":
        interpret = True
    if active is None:
        active = jnp.ones((weights.shape[0],), bool)
    if block_m is None or block_d is None:
        from repro.launch.autotune import tuned_encounter_blocks
        tm, td = tuned_encounter_blocks(*weights.shape)
        block_m = tm if block_m is None else block_m
        block_d = td if block_d is None else block_d
    return encounter_mix_pallas(pos, area, active, weights, radius=radius,
                                block_m=block_m, block_d=block_d,
                                interpret=interpret)
