"""Dispatching wrapper for the fused peer-encounter mix.

``backend``:
- ``"ref"``       — the jnp oracle (engine default; exact, CPU-friendly).
- ``"pallas"``    — the tiled kernel, compiled (TPU) or interpreted per the
                    same autodetect/env override as ``mule_agg``.
- ``"interpret"`` — the tiled kernel, interpreter forced.
- ``"auto"``      — pallas on TPU, ref elsewhere.

``REPRO_PALLAS_INTERPRET`` overrides the interpret autodetect exactly like
``repro.kernels.mule_agg.ops``.

Tile sizes: ``block_m``/``block_d`` left as ``None`` consult the autotune
cache (``repro.launch.autotune.tuned_encounter_blocks`` — the measured
selection committed in ``benchmarks/BENCH_roofline.json``, nearest tuned
[M, D] shape) and fall back to the pre-tuning hand defaults (256, 2048)
without one. Explicit values always win; ``REPRO_TUNE_CACHE`` repoints
(or, empty, disables) the cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.encounter_mix.kernel import (encounter_hop_pallas,
                                                encounter_mix_pallas)
from repro.kernels.encounter_mix.ref import (  # noqa: F401
    encounter_block, encounter_gate, encounter_mix_reference, normalize_mix)
from repro.kernels.mule_agg.ops import _env_interpret


def _resolve(backend: str, interpret: Optional[bool]):
    """Shared backend/interpret resolution for the two dispatchers."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in ("ref", "pallas", "interpret"):
        raise ValueError(f"unknown encounter_mix backend {backend!r}; "
                         "expected ref | pallas | interpret | auto")
    if interpret is None:
        interpret = _env_interpret()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "interpret":
        backend, interpret = "pallas", True
    return backend, interpret


def encounter_mix(pos: jnp.ndarray, area: jnp.ndarray,
                  active: Optional[jnp.ndarray], weights: jnp.ndarray, *,
                  radius: float = 0.15, backend: str = "ref",
                  block_m: int | None = None, block_d: int | None = None,
                  interpret: bool | None = None):
    """pos [M, 2] x area [M] x weights [M, D] -> (mix [M, D], mass [M])."""
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        return encounter_mix_reference(pos, area, active, weights,
                                       radius=radius)
    if active is None:
        active = jnp.ones((weights.shape[0],), bool)
    if block_m is None or block_d is None:
        from repro.launch.autotune import tuned_encounter_blocks
        tm, td = tuned_encounter_blocks(*weights.shape)
        block_m = tm if block_m is None else block_m
        block_d = td if block_d is None else block_d
    return encounter_mix_pallas(pos, area, active, weights, radius=radius,
                                block_m=block_m, block_d=block_d,
                                interpret=interpret)


def encounter_block_hop(pos_r, area_r, act_r, row0, pos_v, area_v, act_v,
                        col0, weights_v, radius: float = 0.15, *,
                        backend: str = "ref",
                        block_m: int | None = None,
                        block_d: int | None = None,
                        interpret: bool | None = None):
    """One ring hop's block partials — the ``encounter_block`` contract
    ((acc [R, D], mass [R]), unnormalized), backend-dispatched.

    ``"ref"`` *is* ``encounter_block`` (the ring stays bitwise-identical
    to its pre-dispatch form); ``"pallas"``/``"interpret"``/``"auto"``
    route through the tiled per-hop kernel with the same tuned-block
    lookup as ``encounter_mix``.
    """
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        return encounter_block(pos_r, area_r, act_r, row0,
                               pos_v, area_v, act_v, col0,
                               weights_v, radius)
    if act_r is None:
        act_r = jnp.ones((pos_r.shape[0],), bool)
    if act_v is None:
        act_v = jnp.ones((pos_v.shape[0],), bool)
    if block_m is None or block_d is None:
        from repro.launch.autotune import tuned_encounter_blocks
        tm, td = tuned_encounter_blocks(*weights_v.shape)
        block_m = tm if block_m is None else block_m
        block_d = td if block_d is None else block_d
    return encounter_hop_pallas(pos_r, area_r, act_r, row0,
                                pos_v, area_v, act_v, col0, weights_v,
                                radius=radius, block_m=block_m,
                                block_d=block_d, interpret=interpret)
