from repro.kernels.encounter_mix.ops import (  # noqa: F401
    encounter_block_hop, encounter_mix)
from repro.kernels.encounter_mix.ref import (  # noqa: F401
    encounter_block, encounter_gate, encounter_mix_reference, normalize_mix)
