from repro.kernels.encounter_mix.ops import encounter_mix  # noqa: F401
from repro.kernels.encounter_mix.ref import (  # noqa: F401
    encounter_block, encounter_gate, encounter_mix_reference, normalize_mix)
