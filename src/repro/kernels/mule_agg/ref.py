"""Oracle for the fused population-aggregation kernel.

out[f, d] = sum_m A[f, m] * W[m, d]

A is the (freshness-filtered, dwell-normalized) assignment matrix
[n_fixed, n_mules]; W is the population's flattened parameters
[n_mules, n_params]. Memory-bound: every byte of W is read once.
"""
from __future__ import annotations

import jax.numpy as jnp


def mule_agg_reference(assign: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return (assign.astype(jnp.float32) @ weights.astype(jnp.float32)).astype(weights.dtype)
