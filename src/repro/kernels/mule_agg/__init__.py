from repro.kernels.mule_agg.ops import mule_agg  # noqa: F401
