"""Dispatching wrapper for the fused population aggregation.

``block_d`` tuning: the kernel streams [M, block_d] tiles; too small pays
grid overhead, too large overflows VMEM residency. ``block_d=None`` uses
``pick_block_d``, which consults the autotune cache — the measured
selection committed in ``benchmarks/BENCH_roofline.json`` by
``repro.launch.autotune`` (re-measure with
``python -m benchmarks.engine_micro --roofline``; the selection is the
argmin of a per-shape candidate sweep on this container's interpret path,
which tracks relative block behaviour, not TPU latency) — and falls back
to the hand-measured constant below when no cache is available.
``REPRO_TUNE_CACHE`` repoints (or, empty, disables) the cache.

``REPRO_PALLAS_INTERPRET`` overrides the interpret-mode autodetect for
every call that doesn't pass ``interpret`` explicitly: set to ``1``/``0``
to force the Pallas interpreter on/off (e.g. exercising the kernel path in
CI on CPU, or dry-running TPU lowering).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.mule_agg.kernel import mule_agg_pallas
from repro.kernels.mule_agg.ref import mule_agg_reference  # noqa: F401

# Pre-cache fallback, measured by the retired kernels_micro block_d sweep on
# this container: the sweep came out monotone at every D (2^12..2^18) —
# per-tile dispatch overhead dominates, so the largest tile always won
# (4096 beat 2048 by ~1.9x at D=2^18). Capped at 4096 to keep the
# [M, block_d] tile + [F, block_d] output VMEM-resident at realistic M.
_BLOCK_D_MEASURED = 4096


def pick_block_d(d: int) -> int:
    """Tuned D-tile size: the autotune cache's selection for the nearest
    measured shape, else the hand-measured fallback constant."""
    from repro.launch.autotune import tuned_block_d
    return tuned_block_d(d, default=_BLOCK_D_MEASURED)


def _env_interpret() -> bool | None:
    val = os.environ.get("REPRO_PALLAS_INTERPRET")
    if not val:                    # unset or empty -> keep the autodetect
        return None
    return val.lower() not in ("0", "false")


def mule_agg(assign, weights, *, block_d: int | None = None,
             backend: str = "auto", interpret: bool | None = None):
    """assign [F, M] x weights [M, D] -> [F, D]."""
    if interpret is None:
        interpret = _env_interpret()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "ref":
        return mule_agg_reference(assign, weights)
    if block_d is None:
        block_d = pick_block_d(weights.shape[1])
    return mule_agg_pallas(assign, weights, block_d=block_d, interpret=interpret)
