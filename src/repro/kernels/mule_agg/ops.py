"""Dispatching wrapper for the fused population aggregation."""
from __future__ import annotations

import jax

from repro.kernels.mule_agg.kernel import mule_agg_pallas
from repro.kernels.mule_agg.ref import mule_agg_reference  # noqa: F401


def mule_agg(assign, weights, *, block_d: int = 2048, backend: str = "auto",
             interpret: bool | None = None):
    """assign [F, M] x weights [M, D] -> [F, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "ref":
        return mule_agg_reference(assign, weights)
    return mule_agg_pallas(assign, weights, block_d=block_d, interpret=interpret)
