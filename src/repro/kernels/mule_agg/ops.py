"""Dispatching wrapper for the fused population aggregation.

``block_d`` tuning: the kernel streams [M, block_d] tiles; too small pays
grid overhead, too large overflows VMEM residency. ``block_d=None`` uses
the measured size from ``pick_block_d`` (re-measure with
``python -m benchmarks.kernels_micro`` — the ``mule_agg.block`` rows sweep
block sizes per D; the pick is the argmin of that sweep on this container's
interpret path, which tracks relative block behaviour, not TPU latency).

``REPRO_PALLAS_INTERPRET`` overrides the interpret-mode autodetect for
every call that doesn't pass ``interpret`` explicitly: set to ``1``/``0``
to force the Pallas interpreter on/off (e.g. exercising the kernel path in
CI on CPU, or dry-running TPU lowering).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.mule_agg.kernel import mule_agg_pallas
from repro.kernels.mule_agg.ref import mule_agg_reference  # noqa: F401

# Measured by benchmarks/kernels_micro.py::run_block_d_sweep on this
# container: the sweep came out monotone at every D (2^12..2^18) — per-tile
# dispatch overhead dominates, so the largest tile always won (4096 beat
# 2048 by ~1.9x at D=2^18) and the "table" collapses to one constant.
# Capped at 4096 to keep the [M, block_d] tile + [F, block_d] output
# VMEM-resident at realistic M (64 x 4096 x 4B = 1 MB streamed tile).
# Re-introduce a (max_d -> block_d) ladder here if a future sweep on real
# hardware yields a non-constant mapping.
_BLOCK_D_MEASURED = 4096


def pick_block_d(d: int) -> int:
    """Measured D-tile size (see the tuning note above)."""
    return _BLOCK_D_MEASURED


def _env_interpret() -> bool | None:
    val = os.environ.get("REPRO_PALLAS_INTERPRET")
    if not val:                    # unset or empty -> keep the autodetect
        return None
    return val.lower() not in ("0", "false")


def mule_agg(assign, weights, *, block_d: int | None = None,
             backend: str = "auto", interpret: bool | None = None):
    """assign [F, M] x weights [M, D] -> [F, D]."""
    if interpret is None:
        interpret = _env_interpret()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "ref":
        return mule_agg_reference(assign, weights)
    if block_d is None:
        block_d = pick_block_d(weights.shape[1])
    return mule_agg_pallas(assign, weights, block_d=block_d, interpret=interpret)
