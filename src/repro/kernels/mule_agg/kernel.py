"""Fused population aggregation as a Pallas TPU kernel.

TPU-native design: the parameter dimension D (typically 10^5—10^9) is tiled
into lane-aligned VMEM blocks of ``block_d`` (multiple of 128). The whole
assignment matrix A [F, M] is tiny (F=8, M=10..10^3) and stays resident in
VMEM across the grid; each grid step streams one [M, block_d] tile of the
population from HBM, does one [F,M]x[M,block_d] MXU matmul, and writes the
[F, block_d] result — a single-pass, memory-bound reduce (arithmetic
intensity ~F MACs/element), which is exactly the roofline behaviour the
aggregation step should have.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(a_ref, w_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)          # [F, M] resident
    w = w_ref[...].astype(jnp.float32)          # [M, block_d] streamed
    o_ref[...] = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mule_agg_pallas(assign: jnp.ndarray, weights: jnp.ndarray, *,
                    block_d: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """assign: [F, M]; weights: [M, D] -> [F, D]."""
    f, m = assign.shape
    m2, d = weights.shape
    assert m == m2, (assign.shape, weights.shape)
    block_d = min(block_d, max(128, d))
    nd = -(-d // block_d)
    d_pad = nd * block_d
    if d_pad != d:
        weights = jnp.pad(weights, ((0, 0), (0, d_pad - d)))

    out = pl.pallas_call(
        _agg_kernel,
        grid=(nd,),
        in_specs=[
            pl.BlockSpec((f, m), lambda i: (0, 0)),           # A resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),     # stream W tiles
        ],
        out_specs=pl.BlockSpec((f, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((f, d_pad), weights.dtype),
        interpret=interpret,
    )(assign, weights)
    return out[:, :d]
