"""Dispatching wrapper for the Mamba2 SSD scan."""
from __future__ import annotations

import jax

from repro.kernels.ssm_scan.ref import ssd_chunked_reference, ssd_reference  # noqa: F401


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk: int = 64, init_state=None,
             backend: str = "ref"):
    """x [B,S,H,P]; dt [B,S,H]; A [H]; Bmat/Cmat [B,S,N] -> (y, final_state)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ssd_chunked_reference(x, dt, A, Bmat, Cmat, chunk=chunk,
                                     init_state=init_state)
    if backend in ("pallas", "interpret"):
        from repro.kernels.ssm_scan.kernel import ssd_scan_pallas
        return ssd_scan_pallas(x, dt, A, Bmat, Cmat, chunk=chunk,
                               init_state=init_state,
                               interpret=(backend == "interpret"))
    raise ValueError(f"unknown backend {backend!r}")
