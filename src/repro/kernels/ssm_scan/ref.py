"""Pure-jnp oracles for the Mamba2 SSD (state-space dual) scan.

The recurrence (per batch b, head h):
    s_i = dA_i * s_{i-1} + dt_i * x_i ⊗ B_i          s: [P, N]
    y_i = C_i · s_i                                   y: [P]
with dA_i = exp(dt_i * A_h), A_h < 0. B/C are shared across heads
(multi-value attention analogue, Mamba2 Sec 7).

- ``ssd_reference``  — direct sequential lax.scan over time (ground truth).
- ``ssd_chunked_reference`` — chunked parallel form (intra-chunk quadratic
  + inter-chunk state carry), the production CPU path; mathematically equal.

Shapes: x [B, S, H, P]; dt [B, S, H]; A [H]; Bmat/Cmat [B, S, N].
Returns y [B, S, H, P] and final state [B, H, P, N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, A, Bmat, Cmat, init_state=None):
    b, s, h, p = x.shape
    n = Bmat.shape[-1]
    dA = jnp.exp(dt * A[None, None, :])                      # [B,S,H]
    dtx = dt[..., None] * x                                   # [B,S,H,P]
    s0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None else init_state

    def step(state, inp):
        dA_t, dtx_t, B_t, C_t = inp
        state = state * dA_t[..., None, None] + jnp.einsum("bhp,bn->bhpn", dtx_t, B_t)
        y = jnp.einsum("bhpn,bn->bhp", state, C_t)
        return state, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dtx, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    state, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked_reference(x, dt, A, Bmat, Cmat, *, chunk: int = 64, init_state=None):
    b, s, h, p = x.shape
    n = Bmat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    loga = (dt * A[None, None, :]).astype(jnp.float32)        # [B,S,H] (<= 0)
    dtx = (dt[..., None] * x).astype(jnp.float32)             # [B,S,H,P]

    def rc(t):  # reshape to chunks, time axis -> (nc, chunk)
        return t.reshape((b, nc, chunk) + t.shape[2:])

    la, dx = rc(loga), rc(dtx)
    Bc, Cc = rc(Bmat.astype(jnp.float32)), rc(Cmat.astype(jnp.float32))
    cum = jnp.cumsum(la, axis=2)                               # [B,nc,Q,H]

    # intra-chunk: y[i] = sum_{j<=i} (C_i·B_j) exp(cum_i - cum_j) dtx_j
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: the upper triangle is exp(+large) = inf, and inf*0
    # poisons gradients through the where
    decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
    L = jnp.exp(decay)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                 # [B,nc,Qi,Qj]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, L, dx)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dtx_j ⊗ B_j
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", dec_end, dx, Bc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # [B,nc,H]

    s0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None else init_state.astype(jnp.float32)

    def carry_fn(state, inp):
        st_c, dec_c = inp                                      # [B,H,P,N], [B,H]
        new = state * dec_c[..., None, None] + st_c
        return new, state                                      # emit state BEFORE chunk

    (final_state, prev_states) = jax.lax.scan(
        carry_fn, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # [B,nc,H,P,N]

    # inter-chunk: y[i] += exp(cum_i) * C_i · S_prev
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp", jnp.exp(cum), Cc, prev_states)

    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)[:, :s]
    return y.astype(x.dtype), final_state
