"""Chunked Mamba2/SSD scan as a Pallas TPU kernel.

TPU-native adaptation (not a port of the CUDA selective-scan):
- grid = (batch*heads, n_chunks); the chunk axis is sequential on TPU, so the
  inter-chunk SSM state [P, N] lives in VMEM scratch and is carried across
  grid steps — the recurrence becomes a systolic sweep over chunks.
- within a chunk the quadratic SSD form runs on the MXU:
  (C B^T ⊙ decay) (dt·x) plus the state broadcast C·S, all fp32.
- B/C are shared across heads (Mamba2 multi-value layout); their BlockSpec
  index_map divides the bh index by the head count, so head replication never
  materializes in HBM.

Chunk size Q and head_dim P should be multiples of 8/128 for clean VMEM
tiling at full scale; interpret mode validates any size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int, nheads: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)          # [Q, 1]
    a = a_ref[0, 0, 0].astype(jnp.float32)      # scalar A_h (negative)
    bmat = b_ref[0].astype(jnp.float32)         # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)         # [Q, N]

    loga = dt[:, 0] * a                         # [Q]
    cum = jnp.cumsum(loga)                      # [Q]
    dtx = dt * x                                # [Q, P]

    # intra-chunk: (C B^T ⊙ L) dtx, L_ij = exp(cum_i - cum_j) for j <= i
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    L = jnp.where(jj <= ii, decay, 0.0)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    y_intra = jax.lax.dot_general(cb * L, dtx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: exp(cum_i) * C_i · S_prev
    state = state_scr[...]                      # [P, N]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # [Q, P]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(cum_last) S + (dec ⊙ dtx)^T B
    dec_end = jnp.exp(cum[-1] - cum)            # [Q]
    sx = dtx * dec_end[:, None]                 # [Q, P]
    state_scr[...] = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        sx, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # [P, N]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, Bmat, Cmat, *, chunk: int = 64,
                    init_state=None, interpret: bool = True):
    """x [B,S,H,P]; dt [B,S,H]; A [H]; Bmat/Cmat [B,S,N] -> (y, final_state).

    final_state is not returned by the kernel (scratch); callers needing the
    state for decode handoff use the chunked reference. init_state must be
    None (prefill-from-scratch), matching how the model uses the kernel.
    """
    assert init_state is None, "kernel path is prefill-from-scratch"
    b, s, h, p = x.shape
    n = Bmat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    sp = nc * chunk

    xr = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dtr = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp, 1)
    ar = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1, 1)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, nheads=h),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, cj: (bh, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, cj, h=h: (bh // h, cj, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, cj, h=h: (bh // h, cj, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, cj: (bh, cj, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, Bmat, Cmat)
    y = jnp.moveaxis(out.reshape(b, h, sp, p), 1, 2)[:, :s]
    # final state recomputed cheaply only when requested downstream; the
    # model's prefill path discards it (decode re-initializes from cache).
    return y, None
