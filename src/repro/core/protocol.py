"""The ML Mule In-House cycles for a single (mule, fixed-device) pair.

These mirror the paper's numbered step lists (Sec 3.1) one-to-one and are
the reference semantics for the vectorized ``population_step`` (tests assert
the two agree). ``population_step`` is what production simulations use.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax.numpy as jnp

from repro.core.aggregation import pairwise_mix


class DeviceState(NamedTuple):
    model: Any
    ts: jnp.ndarray          # last-update time of the carried model


def fixed_device_training_cycle(mule: DeviceState, fixed: DeviceState,
                                threshold: jnp.ndarray, t: jnp.ndarray,
                                train_fixed: Callable[[Any], Any],
                                gamma: float = 0.5):
    """share → filter → aggregate → train(f) → share → aggregate (Fig. 2a).

    Returns (new_mule, new_fixed, accepted: bool).
    """
    # (1) send(m, f, w); (2) freshness filter
    age = t - mule.ts
    accepted = age <= threshold
    # (3) f aggregates accepted model with its own
    g = jnp.where(accepted, gamma, 0.0)
    f_model = pairwise_mix(fixed.model, mule.model, g)
    # (4) f trains on local data
    f_model = train_fixed(f_model)
    # (5) send(f, m, w); (6) m aggregates
    m_model = pairwise_mix(mule.model, f_model, gamma)
    return (DeviceState(m_model, t), DeviceState(f_model, t), accepted)


def mobile_device_training_cycle(mule: DeviceState, fixed: DeviceState,
                                 threshold: jnp.ndarray, t: jnp.ndarray,
                                 train_mule: Callable[[Any], Any],
                                 gamma: float = 0.5):
    """share → filter → aggregate → share → aggregate → train(m) (Fig. 2b)."""
    age = t - mule.ts
    accepted = age <= threshold
    g = jnp.where(accepted, gamma, 0.0)
    # (2-3) f filters + aggregates — the mule "leaves a record of its visit"
    f_model = pairwise_mix(fixed.model, mule.model, g)
    # (4-5) f sends the aggregate back; m aggregates
    m_model = pairwise_mix(mule.model, f_model, gamma)
    # (6) m trains on its local data
    m_model = train_mule(m_model)
    return (DeviceState(m_model, t), DeviceState(f_model, t), accepted)
