"""One method table: every mobile-protocol method as a declarative program.

The engine used to keep two hand-maintained dispatch tables — the
single-host ``make_method_step`` and the distributed
``make_distributed_method_step`` — that had to agree method by method on
cadence, key discipline, and churn semantics, and that drifted in coverage
(the peer-encounter baselines never made it into the distributed table).
``MethodProgram`` replaces both: a method *declares* its per-step pieces
once, and one compiler lowers the declaration to either engine, so the two
lanes cannot drift by construction.

A program is three optional pieces, executed in this order each step:

- ``space_exchange``  — the ML Mule space-mediated cycle (deliver →
  freshness filter → dwell-weighted segment-reduce at fixed devices →
  train → send back). Lowering: single host runs ``population_step``;
  distributed runs the fused collective schedule (every per-step reduction
  packed into ONE ``psum``).
- ``peer_exchange``   — a device-to-device encounter op (``"gossip"`` |
  ``"oppcl"``), fired at the ``peer_every`` cadence (paper Sec 4.3.1: a
  peer hand-off costs 3 steps) as a ``lax.cond`` on the step index, keyed
  with ``fold_in(key, peer_key_fold)`` when riding alongside a space
  exchange. Lowering: single host calls the baseline step over the full
  population (the fused ``encounter_mix`` op); distributed wraps it in a
  ring ``ppermute`` exchange that streams each shard's (pos, area, active,
  payload) block around the mesh mule axis (``RingSpec``), so the search
  crosses shards without ever gathering the population.
- ``local_train``     — one local step on the training side (per
  ``cfg.mode``), no communication.

Activity-mask semantics are part of the contract, not per-method code: the
space exchange folds ``info["active"]`` into its delivery mask, peer
exchanges drop inactive mules from both sides of the encounter test and
``apply_activity_mask`` carries their models bitwise, and local training
where-selects old leaves back in.

Adding method #6
----------------
Add one ``MethodProgram`` entry (and the name to
``repro.core.population.METHODS_MOBILE``); both engines, the sweep lanes,
and the jit cache pick it up with no further dispatch code. A hybrid like
``mlmule+gossip`` is just ``space_exchange=True, peer_exchange="gossip",
peer_key_fold=1``; a faster-cadence gossip is ``peer_every=1``. Pieces that
don't exist yet (a new exchange op) plug in by extending ``_PEER_STEPS``
with a function of the ``gossip_step`` signature — the compiler treats the
op as data. ``tests/test_method_program.py`` exercises exactly this path
with a synthetic sixth method.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.gossip import N_AREA_BITS, RingSpec, gossip_step
from repro.baselines.local_only import local_step
from repro.baselines.oppcl import oppcl_step
from repro.core.freshness import age_bin_onehot, sketch_push_and_update
from repro.core.population import (METHODS_MOBILE, PopulationConfig, TrainFn,
                                   apply_activity_mask, population_step)


@dataclasses.dataclass(frozen=True)
class MethodProgram:
    """Declarative per-step pieces of one mobile-protocol method."""
    name: str
    space_exchange: bool = False        # ML Mule share-aggregate cycle
    peer_exchange: Optional[str] = None  # None | "gossip" | "oppcl"
    peer_every: int = 3                  # cadence: fires at t % k == k - 1
    peer_key_fold: Optional[int] = None  # fold_in(key, n) for the peer draw
    local_train: bool = False            # per-device local step, no comms


METHOD_PROGRAMS: Dict[str, MethodProgram] = {
    "mlmule": MethodProgram("mlmule", space_exchange=True),
    "gossip": MethodProgram("gossip", peer_exchange="gossip"),
    "oppcl": MethodProgram("oppcl", peer_exchange="oppcl"),
    "local": MethodProgram("local", local_train=True),
    "mlmule+gossip": MethodProgram("mlmule+gossip", space_exchange=True,
                                   peer_exchange="gossip", peer_key_fold=1),
}

_PEER_STEPS: Dict[str, Callable] = {"gossip": gossip_step, "oppcl": oppcl_step}


def get_program(method: str) -> MethodProgram:
    if method not in METHOD_PROGRAMS:
        raise ValueError(f"unknown method {method!r}; "
                         f"expected one of {METHODS_MOBILE}")
    return METHOD_PROGRAMS[method]


# ---------------------------------------------------------------------------
# single-host lowering
# ---------------------------------------------------------------------------


def compile_step(program: MethodProgram, train_fn: TrainFn,
                 cfg: PopulationConfig, area: jnp.ndarray) -> Callable:
    """Lower a program to the single-host scan step.

    Uniform signature ``step(state, info, batches, key) -> state`` with
    ``info`` carrying ``fixed_id``/``exchange``/``pos``/``t`` (and
    optionally ``active``); ``area`` is the per-mule area vector the
    peer-encounter ops need. On mobility scenarios whose area is a
    time-varying [T, M] trace, the scan threads the current row through
    ``info["area"]`` instead and the closed-over ``area`` is only the
    fallback. Semantics are bitwise-pinned to the per-step reference
    driver (``repro.scenarios.run_population_loop``).
    """
    peer_fn = (_PEER_STEPS[program.peer_exchange]
               if program.peer_exchange else None)
    if cfg.mode == "fixed":
        local_side, local_bkey = "fixed_models", "fixed"
    else:
        local_side, local_bkey = "mule_models", "mule"

    def step(st, info, batches, key):
        if program.space_exchange:
            st = population_step(st, info, batches, train_fn, cfg, key)
        if program.local_train:
            trained = local_step(st[local_side], batches[local_bkey],
                                 train_fn, key)
            if local_side == "mule_models":
                trained = apply_activity_mask(info.get("active"), trained,
                                              st[local_side])
            st = {**st, local_side: trained}
        if peer_fn is not None:
            kp = (key if program.peer_key_fold is None
                  else jax.random.fold_in(key, program.peer_key_fold))
            act = info.get("active")

            def exchange(models):
                new = peer_fn(models, info["pos"], info.get("area", area),
                              batches["mule"], train_fn, kp, active=act,
                              backend=cfg.enc_backend)
                return apply_activity_mask(act, new, models)

            k = program.peer_every
            models = jax.lax.cond(info["t"] % k == k - 1, exchange,
                                  lambda m: m, st["mule_models"])
            st = {**st, "mule_models": models}
        return st

    return step


# ---------------------------------------------------------------------------
# distributed (shard_map) lowering
# ---------------------------------------------------------------------------


def _local_block(dcfg, leaf, m_loc):
    """Slice this shard's mule rows from a replicated [M, ...] array."""
    if leaf.shape[0] == m_loc:
        return leaf                           # already shard-local
    i = jax.lax.axis_index(dcfg.data_axis)
    return jax.lax.dynamic_slice_in_dim(leaf, i * m_loc, m_loc, axis=0)


def _mule_train_keys(dcfg, key, m_loc):
    """Global split + shard slice: per-mule draws match single host."""
    return _local_block(dcfg, jax.random.split(key, dcfg.pop.n_mules), m_loc)


def compile_distributed_step(program: MethodProgram, train_fn: Callable,
                             dcfg, *, ring_size: Optional[int] = None
                             ) -> Callable:
    """Lower a program to the shard-local distributed scan step.

    Same ``(state, info, batches, key) -> state`` signature, but every
    array with a leading mule axis is this shard's block and the step must
    run inside ``shard_map`` over ``dcfg.data_axis``; ``info`` additionally
    carries the shard-local ``"area"`` block. ``ring_size`` is the static
    data-axis size the peer-exchange ring unrolls over (required for peer
    programs; the engines read it off the mesh). ``dcfg.ring_prune``
    toggles the ring's exact area-bitmask hop pruning, and
    ``cfg.enc_backend`` selects the per-hop block math
    (``encounter_block_hop``), mirroring the single-host lowering.

    Key discipline mirrors the single-host lowering exactly: fixed-mode
    training splits the replicated key over ``n_fixed``; every per-mule
    draw (mobile training, peer-exchange training) splits it over the
    *global* ``n_mules`` and slices the local block, so sharded runs equal
    single-host runs row for row regardless of shard count.
    """
    cfg = dcfg.pop
    if program.peer_exchange and ring_size is None:
        raise ValueError(
            f"method {program.name!r} needs the mesh to size its ring "
            "exchange; pass mesh= to make_distributed_method_step")

    space_step = (_space_exchange_distributed(train_fn, dcfg)
                  if program.space_exchange else None)
    peer_fn = (_PEER_STEPS[program.peer_exchange]
               if program.peer_exchange else None)

    def step(st, info, batches, key):
        if space_step is not None:
            st = space_step(st, info, batches, key)
        if program.local_train:
            if cfg.mode == "fixed":
                keys = jax.random.split(key, cfg.n_fixed)
                trained = jax.vmap(train_fn)(st["fixed_models"],
                                             batches["fixed"], keys)
                st = {**st, "fixed_models": trained}
            else:
                m_loc = info["fixed_id"].shape[0]
                mb = jax.tree.map(lambda l: _local_block(dcfg, l, m_loc),
                                  batches["mule"])
                keys = _mule_train_keys(dcfg, key, m_loc)
                trained = jax.vmap(train_fn)(st["mule_models"], mb, keys)
                trained = apply_activity_mask(info.get("active"), trained,
                                              st["mule_models"])
                st = {**st, "mule_models": trained}
        if peer_fn is not None:
            kp = (key if program.peer_key_fold is None
                  else jax.random.fold_in(key, program.peer_key_fold))
            act = info.get("active")
            m_loc = info["fixed_id"].shape[0]
            ring = RingSpec(dcfg.data_axis, ring_size,
                            prune=getattr(dcfg, "ring_prune", True),
                            n_bits=(getattr(dcfg, "ring_bits", 0)
                                    or N_AREA_BITS))

            def exchange(models):
                # key split and batch slice stay inside the branch so the
                # ~(k-1)/k off-cadence steps pay nothing for them
                mb = jax.tree.map(lambda l: _local_block(dcfg, l, m_loc),
                                  batches["mule"])
                keys = _mule_train_keys(dcfg, kp, m_loc)
                new = peer_fn(models, info["pos"], info["area"], mb,
                              train_fn, kp, active=act,
                              backend=cfg.enc_backend, ring=ring, keys=keys)
                return apply_activity_mask(act, new, models)

            k = program.peer_every
            models = jax.lax.cond(info["t"] % k == k - 1, exchange,
                                  lambda m: m, st["mule_models"])
            st = {**st, "mule_models": models}
        return st

    return step


def _space_exchange_distributed(train_fn: Callable, dcfg) -> Callable:
    """The ML Mule cycle with the fused segment-reduce + ONE psum schedule.

    Every per-step reduction — model contributions of all leaves, receipt
    counts, and the freshness statistic (age moments or histogram bins) —
    is packed into columns of a single [F, ...] matrix so the whole step
    costs exactly one collective (an ``ordered_psum``: all_gather plus a
    rank-order fold, so the float reduction order is identical across
    backends and process counts). On a scan of thousands of steps the
    collective rendezvous is the dominant cost; fusing ~10 all-reduces
    into 1 is most of the engine's win.
    """
    from repro.core.distributed import _tree_mix, ordered_psum
    cfg = dcfg.pop
    fcfg = cfg.freshness
    axes = ((dcfg.pod_axis, dcfg.data_axis) if dcfg.pod_axis
            else (dcfg.data_axis,))
    reduce_axes = axes if dcfg.cross_pod else (dcfg.data_axis,)

    def step(st, info, batches, key):
        t = st["t"]
        fid = info["fixed_id"]
        m_loc = fid.shape[0]
        deliver = info["exchange"] & (fid >= 0)
        if info.get("active") is not None:
            # churn folds into the delivery mask, so inactive mules vanish
            # from the fused psum payload (model columns, counts, and the
            # freshness statistic alike) — distributed == single-host
            # under any mask by construction
            deliver = deliver & info["active"]
        ages = t - st["mule_ts"]
        fresh = st["fresh"]
        thr = fresh["threshold"][jnp.maximum(fid, 0)]
        if fcfg.stat == "median":
            warm = fresh["count"][jnp.maximum(fid, 0)] < fcfg.warmup
            fresh_ok = deliver & (warm | (ages <= thr))
        else:
            # legacy semantics preserved from the retired per-step path:
            # meanstd carries no receipt counts, so FreshnessConfig.warmup
            # is ignored — acceptance is the bare threshold test
            fresh_ok = deliver & (ages <= thr)

        # -- fused segment-reduce + ONE all-reduce ---------------------------
        onehot = jax.nn.one_hot(jnp.maximum(fid, 0), cfg.n_fixed, axis=0)
        a_loc = onehot * fresh_ok[None, :].astype(jnp.float32)  # [F, M_loc]
        leaves, treedef = jax.tree.flatten(st["mule_models"])
        shapes = [l.shape[1:] for l in leaves]
        sizes = [int(np.prod(s)) if s else 1 for s in shapes]
        flat = jnp.concatenate(
            [l.reshape(m_loc, -1).astype(jnp.float32) for l in leaves]
            + [jnp.ones((m_loc, 1), jnp.float32)], axis=1)
        cols_a = [a_loc @ flat]                # models | counts  [F, D+1]
        if fcfg.stat == "meanstd":
            cols_a.append(a_loc @ jnp.stack([ages, ages ** 2], axis=1))
        else:
            d_loc = onehot * deliver[None, :].astype(jnp.float32)
            bins = age_bin_onehot(ages, fcfg)                  # [M_loc, B]
            cols_a.append(d_loc @ jnp.concatenate(
                [bins, jnp.ones((m_loc, 1), jnp.float32)], axis=1))
        # ordered_psum, not lax.psum: the fold order of this float payload
        # must not depend on the backend, or multi-process runs drift ULPs
        # off the single-process bitwise pins (integer reductions elsewhere
        # are exact and stay raw)
        fused = ordered_psum(jnp.concatenate(cols_a, axis=1), reduce_axes)

        d_total = sum(sizes)
        part_flat = fused[:, :d_total]
        counts = fused[:, d_total]
        has = (counts > 0).astype(jnp.float32)
        norm = part_flat / jnp.maximum(counts, 1.0)[:, None]
        outs, off = [], 0
        for s, n, l in zip(shapes, sizes, leaves):
            outs.append(norm[:, off:off + n]
                        .reshape((cfg.n_fixed,) + s).astype(l.dtype))
            off += n
        agg = jax.tree.unflatten(treedef, outs)
        gamma = (cfg.gamma / (1.0 + cfg.prox_mu)
                 if cfg.aggregation == "prox" else cfg.gamma)
        fixed_models = _tree_mix(st["fixed_models"], agg, gamma * has)

        # -- freshness threshold update --------------------------------------
        if fcfg.stat == "median":
            # paper semantics: every *delivered* age is pushed (accepted or
            # not). Mule shards are replicated across pods, so a cross_pod
            # reduce folds n_pods copies into the histogram and counts;
            # quantiles are scale-invariant but warmup counts are not, so
            # both are divided back down (psum of a literal is the axis
            # size, folded at compile time — no extra collective).
            n_rep = (jax.lax.psum(1, dcfg.pod_axis)
                     if dcfg.pod_axis and dcfg.cross_pod else 1)
            step_hist = fused[:, d_total + 1:-1] / n_rep
            step_cnt = fused[:, -1] / n_rep
            fresh = sketch_push_and_update(fresh, step_hist, step_cnt, fcfg)
        else:
            # legacy deviation: EMA of this step's accepted-age mean/std
            age_sum, age_sq = fused[:, -2], fused[:, -1]
            mean_age = age_sum / jnp.maximum(counts, 1.0)
            var_age = jnp.maximum(
                age_sq / jnp.maximum(counts, 1.0) - mean_age ** 2, 0.0)
            target = mean_age + fcfg.beta * jnp.sqrt(var_age)
            fresh = {"threshold": jnp.where(
                counts > 0,
                (1 - fcfg.alpha) * fresh["threshold"] + fcfg.alpha * target,
                fresh["threshold"])}

        # -- training + send-back (paper Fig. 2 cycles) ----------------------
        if cfg.mode == "fixed":
            keys = jax.random.split(key, cfg.n_fixed)
            trained = jax.vmap(train_fn)(fixed_models, batches["fixed"],
                                         keys)
            fixed_models = _tree_mix(fixed_models, trained, has)

        per_mule_fixed = jax.tree.map(
            lambda l: l[jnp.maximum(fid, 0)], fixed_models)
        gm = cfg.gamma * deliver.astype(jnp.float32)
        mule_models = _tree_mix(st["mule_models"], per_mule_fixed, gm)

        if cfg.mode == "mobile":
            mb = jax.tree.map(lambda l: _local_block(dcfg, l, m_loc),
                              batches["mule"])
            keys = _mule_train_keys(dcfg, key, m_loc)
            trained = jax.vmap(train_fn)(mule_models, mb, keys)
            mule_models = _tree_mix(mule_models, trained,
                                    deliver.astype(jnp.float32))

        return {
            "mule_models": mule_models,
            "fixed_models": fixed_models,
            "mule_ts": jnp.where(deliver, t, st["mule_ts"]),
            "fresh": fresh,
            "t": t + 1.0,
        }

    return step
