"""Model aggregation primitives over stacked-pytree populations.

A population of P models is a pytree whose leaves have a leading P axis.
``masked_group_mean`` is ML Mule's aggregation hot spot: every fixed device
averages the (freshness-filtered, dwell-weighted) models delivered by its
co-located mules — a [F, M] × [M, D] reduce over every parameter. The
Pallas ``mule_agg`` kernel implements the fused tiled version; the jnp path
is the oracle and CPU fallback.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def weighted_average(models: Any, weights: jnp.ndarray) -> Any:
    """models: stacked pytree [P, ...]; weights: [P] (need not sum to 1)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def avg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf * wb, axis=0)

    return jax.tree.map(avg, models)


def pairwise_mix(a: Any, b: Any, gamma) -> Any:
    """a <- (1-gamma) a + gamma b; gamma scalar or broadcastable per-leaf."""
    return jax.tree.map(lambda x, y: (1.0 - gamma) * x + gamma * y, a, b)


def batched_mix(a: Any, b: Any, gamma: jnp.ndarray) -> Any:
    """Stacked [P,...] mix with per-member gamma [P]."""
    def mix(x, y):
        g = gamma.reshape((-1,) + (1,) * (x.ndim - 1))
        return (1.0 - g) * x + g * y

    return jax.tree.map(mix, a, b)


def prox_mix(local: Any, incoming: Any, gamma, mu: float = 0.1) -> Any:
    """FedProx-style aggregation (paper Sec 3.1 lists FedProx/FedDyn/SCAFFOLD
    as drop-in replacements): the mix is pulled toward the local model by a
    proximal term — equivalent to mixing with an effective rate
    gamma' = gamma / (1 + mu), which damps drift from stale mules."""
    eff = gamma / (1.0 + mu)
    return pairwise_mix(local, incoming, eff)


def quality_weights(losses: jnp.ndarray, temperature: float = 1.0) -> jnp.ndarray:
    """Model-quality aggregation weights (paper cites IDML [44]): softmax of
    negative validation losses — better snapshots count more."""
    return jax.nn.softmax(-losses / jnp.maximum(temperature, 1e-6))


def masked_group_mean(models: Any, assign: jnp.ndarray, *,
                      backend: str = "ref") -> Any:
    """Weighted group means: out[f] = sum_m A[f,m] models[m] / sum_m A[f,m].

    models: stacked pytree [M, ...]; assign: [F, M] non-negative weights
    (zero = not delivering to that fixed device). Rows with zero mass return
    zeros — callers mask on ``row_mass``.
    Returns (grouped pytree [F, ...], row_mass [F]).
    """
    mass = jnp.sum(assign, axis=1)                       # [F]
    norm = assign / jnp.maximum(mass, 1e-12)[:, None]    # [F, M]

    if backend in ("pallas", "interpret"):
        from repro.kernels.mule_agg.ops import mule_agg
        leaves, treedef = jax.tree.flatten(models)
        shapes = [l.shape[1:] for l in leaves]
        sizes = [int(jnp.prod(jnp.array(s))) if s else 1 for s in shapes]
        flat = jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves], axis=1)
        out = mule_agg(norm.astype(jnp.float32), flat,
                       interpret=(backend == "interpret"))
        outs, off = [], 0
        for s, n, l in zip(shapes, sizes, leaves):
            outs.append(out[:, off:off + n].reshape((out.shape[0],) + s).astype(l.dtype))
            off += n
        return jax.tree.unflatten(treedef, outs), mass

    def agg(leaf):
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        out = norm.astype(jnp.float32) @ flat
        return out.reshape((assign.shape[0],) + leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree.map(agg, models), mass
