"""The paper's model-freshness filter (Sec 3.1).

Each fixed device f keeps a history L of the *ages* of models it has
received (age = now - model's last update time) and a dynamic threshold

    T_{t+1} = (1 - alpha) T_t + alpha * ( median(L) + beta * MAD(L) )

where MAD is the median absolute deviation. An incoming model is accepted
iff its age <= T (devices in warmup accept everything).

The paper does not give alpha/beta values; defaults alpha=0.1, beta=1.0 are
our documented assumption. History is a fixed ring buffer per device.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INF = jnp.float32(1e30)


@dataclasses.dataclass(frozen=True)
class FreshnessConfig:
    alpha: float = 0.1
    beta: float = 1.0
    history: int = 16         # ring buffer length K
    warmup: int = 4           # accept-all until this many receipts
    init_threshold: float = 1e6


def init_freshness(n_fixed: int, cfg: FreshnessConfig):
    return {
        "ages": jnp.full((n_fixed, cfg.history), INF),     # ring buffer of ages
        "count": jnp.zeros((n_fixed,), jnp.int32),
        "threshold": jnp.full((n_fixed,), cfg.init_threshold, jnp.float32),
    }


def accept_mask(state, fixed_ids: jnp.ndarray, ages: jnp.ndarray,
                cfg: FreshnessConfig) -> jnp.ndarray:
    """fixed_ids: [M] target device per mule (-1 = none); ages: [M]."""
    fid = jnp.maximum(fixed_ids, 0)
    thr = state["threshold"][fid]
    warm = state["count"][fid] < cfg.warmup
    return (fixed_ids >= 0) & (warm | (ages <= thr))


def _masked_median(vals: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Median over valid entries of each row (midpoint for even counts)."""
    filled = jnp.where(valid, vals, INF)
    srt = jnp.sort(filled, axis=-1)
    n = jnp.sum(valid, axis=-1)                           # [F]
    lo = jnp.maximum(n - 1, 0) // 2
    hi = jnp.maximum(n, 1) // 2
    vlo = jnp.take_along_axis(srt, lo[:, None], axis=-1)[:, 0]
    vhi = jnp.take_along_axis(srt, hi[:, None], axis=-1)[:, 0]
    return 0.5 * (vlo + vhi)


def push_and_update(state, fixed_ids: jnp.ndarray, ages: jnp.ndarray,
                    deliver: jnp.ndarray, cfg: FreshnessConfig):
    """Push delivered ages into per-device rings, then update thresholds.

    fixed_ids/ages/deliver: [M] per-mule target, age, delivering-this-step.
    Sequential scan over mules keeps the ring semantics exact for multiple
    deliveries to one device in the same step.
    """
    def push(carry, inp):
        ages_buf, count = carry
        fid, age, dlv = inp

        def do(args):
            ages_buf, count = args
            f = jnp.maximum(fid, 0)
            slot = count[f] % cfg.history
            ages_buf = ages_buf.at[f, slot].set(age)
            count = count.at[f].add(1)
            return ages_buf, count

        carry = jax.lax.cond(dlv & (fid >= 0), do, lambda a: a, (ages_buf, count))
        return carry, None

    (ages_buf, count), _ = jax.lax.scan(
        push, (state["ages"], state["count"]),
        (fixed_ids, ages.astype(jnp.float32), deliver))

    valid = ages_buf < INF
    med = _masked_median(ages_buf, valid)
    mad = _masked_median(jnp.abs(ages_buf - med[:, None]), valid)
    target = med + cfg.beta * mad
    any_hist = jnp.any(valid, axis=-1)
    new_thr = jnp.where(
        any_hist,
        (1 - cfg.alpha) * state["threshold"] + cfg.alpha * target,
        state["threshold"])
    return {"ages": ages_buf, "count": count, "threshold": new_thr}
