"""The paper's model-freshness filter (Sec 3.1).

Each fixed device f keeps a history L of the *ages* of models it has
received (age = now - model's last update time) and a dynamic threshold

    T_{t+1} = (1 - alpha) T_t + alpha * ( median(L) + beta * MAD(L) )

where MAD is the median absolute deviation. An incoming model is accepted
iff its age <= T (devices in warmup accept everything).

The paper does not give alpha/beta values; defaults alpha=0.1, beta=1.0 are
our documented assumption. History is a fixed ring buffer per device.

Two statistics back the threshold:

- the exact ring buffer (``init_freshness`` / ``push_and_update``) — the
  single-host engine's path. The ring push is a sequential scan over mules
  (slot order matters), which is NOT associative and therefore cannot be
  merged with a ``psum`` across population shards.
- an associative histogram sketch (``init_freshness_sketch`` /
  ``sketch_push_and_update``) — ages are binned into a fixed per-device
  histogram; per-step shard contributions are plain sums, so the
  distributed engine merges them with one ``psum`` and recovers
  median/MAD from the merged histogram (``sketch_median_mad``) to
  interpolated-bin accuracy. ``FreshnessConfig.stat`` selects between the
  sketch (``"median"``, paper semantics) and the legacy ``"meanstd"``
  mean/std deviation the distributed engine used before.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# np, not jnp: a module-level jnp scalar would initialize the jax backend
# at import time, which breaks jax.distributed bring-up (initialize()
# must run before the first computation); as a traced constant the two
# are bitwise identical
INF = np.float32(1e30)


@dataclasses.dataclass(frozen=True)
class FreshnessConfig:
    alpha: float = 0.1
    beta: float = 1.0
    history: int = 16         # ring buffer length K
    warmup: int = 4           # accept-all until this many receipts
    init_threshold: float = 1e6
    # distributed-engine statistic: "median" (associative histogram sketch,
    # matches the paper's Sec 3.1 median/MAD) or "meanstd" (per-step
    # mean/std EMA — the engine's former documented deviation; carries no
    # receipt counts, so ``warmup`` is ignored there). The single-host
    # engine always uses the exact ring buffer above.
    stat: str = "median"
    sketch_bins: int = 64     # histogram resolution B (error ~ max_age/B)
    sketch_max_age: float = 512.0  # ages above clamp into the last bin


def init_freshness(n_fixed: int, cfg: FreshnessConfig):
    return {
        "ages": jnp.full((n_fixed, cfg.history), INF),     # ring buffer of ages
        "count": jnp.zeros((n_fixed,), jnp.int32),
        "threshold": jnp.full((n_fixed,), cfg.init_threshold, jnp.float32),
    }


def accept_mask(state, fixed_ids: jnp.ndarray, ages: jnp.ndarray,
                cfg: FreshnessConfig) -> jnp.ndarray:
    """fixed_ids: [M] target device per mule (-1 = none); ages: [M]."""
    fid = jnp.maximum(fixed_ids, 0)
    thr = state["threshold"][fid]
    warm = state["count"][fid] < cfg.warmup
    return (fixed_ids >= 0) & (warm | (ages <= thr))


def _masked_median(vals: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Median over valid entries of each row (midpoint for even counts)."""
    filled = jnp.where(valid, vals, INF)
    srt = jnp.sort(filled, axis=-1)
    n = jnp.sum(valid, axis=-1)                           # [F]
    lo = jnp.maximum(n - 1, 0) // 2
    hi = jnp.maximum(n, 1) // 2
    vlo = jnp.take_along_axis(srt, lo[:, None], axis=-1)[:, 0]
    vhi = jnp.take_along_axis(srt, hi[:, None], axis=-1)[:, 0]
    return 0.5 * (vlo + vhi)


def push_and_update(state, fixed_ids: jnp.ndarray, ages: jnp.ndarray,
                    deliver: jnp.ndarray, cfg: FreshnessConfig):
    """Push delivered ages into per-device rings, then update thresholds.

    fixed_ids/ages/deliver: [M] per-mule target, age, delivering-this-step.
    Sequential scan over mules keeps the ring semantics exact for multiple
    deliveries to one device in the same step.
    """
    def push(carry, inp):
        ages_buf, count = carry
        fid, age, dlv = inp

        def do(args):
            ages_buf, count = args
            f = jnp.maximum(fid, 0)
            slot = count[f] % cfg.history
            ages_buf = ages_buf.at[f, slot].set(age)
            count = count.at[f].add(1)
            return ages_buf, count

        carry = jax.lax.cond(dlv & (fid >= 0), do, lambda a: a, (ages_buf, count))
        return carry, None

    (ages_buf, count), _ = jax.lax.scan(
        push, (state["ages"], state["count"]),
        (fixed_ids, ages.astype(jnp.float32), deliver))

    valid = ages_buf < INF
    med = _masked_median(ages_buf, valid)
    mad = _masked_median(jnp.abs(ages_buf - med[:, None]), valid)
    target = med + cfg.beta * mad
    any_hist = jnp.any(valid, axis=-1)
    new_thr = jnp.where(
        any_hist,
        (1 - cfg.alpha) * state["threshold"] + cfg.alpha * target,
        state["threshold"])
    return {"ages": ages_buf, "count": count, "threshold": new_thr}


# ---------------------------------------------------------------------------
# associative median/MAD sketch (distributed engine)
# ---------------------------------------------------------------------------
#
# A per-device age histogram over B fixed bins. Binning is a sum, so shard
# contributions merge under ``psum``; median and MAD are then weighted
# quantiles of the merged histogram, exact to within one bin width. The ring
# buffer's last-K window is emulated by capping the resident histogram mass
# at K after each push (old receipts decay geometrically instead of being
# evicted slot-by-slot — the one semantic difference from the exact ring).


def sketch_edges(cfg: FreshnessConfig) -> jnp.ndarray:
    """Bin edges [B+1]: uniform over [0, sketch_max_age]."""
    return jnp.linspace(0.0, cfg.sketch_max_age, cfg.sketch_bins + 1)


def sketch_centers(cfg: FreshnessConfig) -> jnp.ndarray:
    e = sketch_edges(cfg)
    return 0.5 * (e[:-1] + e[1:])


def age_bin_onehot(ages: jnp.ndarray, cfg: FreshnessConfig) -> jnp.ndarray:
    """One-hot bin membership per age: [...] -> [..., B].

    Ages below 0 / above ``sketch_max_age`` clamp into the edge bins, so no
    mass is lost (the threshold comparison saturates the same way).
    """
    b = cfg.sketch_bins
    width = cfg.sketch_max_age / b
    idx = jnp.clip(jnp.floor(ages / width).astype(jnp.int32), 0, b - 1)
    return jax.nn.one_hot(idx, b, dtype=jnp.float32)


def age_histogram(ages: jnp.ndarray, weights: jnp.ndarray,
                  cfg: FreshnessConfig) -> jnp.ndarray:
    """Weighted histogram over the trailing axis: [..., N] -> [..., B]."""
    onehot = age_bin_onehot(ages, cfg)                          # [..., N, B]
    return jnp.sum(onehot * weights[..., None].astype(jnp.float32), axis=-2)


def hist_quantile(hist: jnp.ndarray, edges: jnp.ndarray,
                  q: float) -> jnp.ndarray:
    """Interpolated weighted quantile per row: hist [..., B] -> [...]."""
    c = jnp.cumsum(hist, axis=-1)
    total = c[..., -1:]
    t = q * total
    idx = jnp.argmax(c >= t, axis=-1)                           # first cross
    cprev = jnp.where(
        idx > 0,
        jnp.take_along_axis(c, jnp.maximum(idx - 1, 0)[..., None],
                            axis=-1)[..., 0], 0.0)
    mass = jnp.take_along_axis(hist, idx[..., None], axis=-1)[..., 0]
    frac = jnp.clip((t[..., 0] - cprev) / jnp.maximum(mass, 1e-12), 0.0, 1.0)
    width = edges[1] - edges[0]
    return edges[idx] + frac * width


def sketch_median_mad(hist: jnp.ndarray, cfg: FreshnessConfig):
    """(median, MAD) of the binned ages: hist [..., B] -> ([...], [...]).

    MAD is the weighted median of |bin center - median| — bins are sorted
    by distance from the median and the 0.5-mass crossing is taken.

    Accuracy: each estimate lands within one bin width of the sample order
    statistics bracketing the 0.5 quantile (``numpy``'s midpoint convention
    can sit anywhere inside that bracket, so on sparse histories the gap to
    ``jnp.median`` is bounded by the middle-sample spacing, and on dense
    histories both converge to bin resolution — tests pin both regimes).
    """
    edges = sketch_edges(cfg)
    med = hist_quantile(hist, edges, 0.5)
    d = jnp.abs(sketch_centers(cfg) - med[..., None])           # [..., B]
    order = jnp.argsort(d, axis=-1)
    ds = jnp.take_along_axis(d, order, axis=-1)
    ws = jnp.take_along_axis(hist, order, axis=-1)
    cw = jnp.cumsum(ws, axis=-1)
    total = cw[..., -1:]
    idx = jnp.argmax(cw >= 0.5 * total, axis=-1)
    mad = jnp.take_along_axis(ds, idx[..., None], axis=-1)[..., 0]
    return med, mad


def init_freshness_sketch(n_fixed: int, cfg: FreshnessConfig):
    return {
        "hist": jnp.zeros((n_fixed, cfg.sketch_bins), jnp.float32),
        "count": jnp.zeros((n_fixed,), jnp.int32),
        "threshold": jnp.full((n_fixed,), cfg.init_threshold, jnp.float32),
    }


def sketch_push_and_update(state, step_hist: jnp.ndarray,
                           step_counts: jnp.ndarray, cfg: FreshnessConfig):
    """Fold one step's (already psum-merged) histogram into the sketch.

    step_hist [F, B] / step_counts [F]: this step's delivered-age histogram
    and receipt counts, summed across population shards by the caller. The
    update itself runs on replicated state, so every shard computes the
    identical new sketch.
    """
    hist = state["hist"] + step_hist
    count = state["count"] + step_counts.astype(jnp.int32)
    total = jnp.sum(hist, axis=-1)
    # cap resident mass at the ring depth K: the sketch's last-K window
    scale = jnp.where(total > cfg.history,
                      cfg.history / jnp.maximum(total, 1e-12), 1.0)
    hist = hist * scale[:, None]
    med, mad = sketch_median_mad(hist, cfg)
    target = med + cfg.beta * mad
    new_thr = jnp.where(
        jnp.sum(hist, axis=-1) > 0,
        (1 - cfg.alpha) * state["threshold"] + cfg.alpha * target,
        state["threshold"])
    return {"hist": hist, "count": count, "threshold": new_thr}
