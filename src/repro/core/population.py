"""Vectorized ML Mule population engine.

The whole device population is simulated as stacked pytrees:
mule models [M, ...], fixed-device models [F, ...]. One ``population_step``
executes the paper's In-House cycles for every concurrent co-location in a
single masked batched update:

fixed-device training (share-aggregate-train-share, Fig. 2a):
  1. mules with a completed exchange deliver snapshots to their fixed device
  2. freshness filter (dynamic threshold) drops stale snapshots
  3. each fixed device folds the dwell-weighted mean of accepted snapshots
     into its model (masked_group_mean — the ``mule_agg`` hot spot)
  4. fixed devices that received anything train one step on local data
  5. mules receive the updated model back and fold it into their own

mobile-device training (share-aggregate-share-train, Fig. 2b):
  steps 1–3 identical (the mule "leaves a record of having visited");
  4'. mules receive the aggregated model back and fold it in
  5'. mules train one step on their own data

The Mule phase is implicit: a mule not co-located simply carries its model
(its timestamp ages, which is what the freshness filter measures).

``make_method_step`` generalizes the step to every mobile-protocol method
the paper compares (``METHODS_MOBILE``): ML Mule above, plus the
decentralized baselines (gossip / oppcl / local-only and the mlmule+gossip
hybrid). All of them share one traceable signature
``(state, info, batches, key) -> state`` so the scan engine
(``repro.scenarios.engine``) can replay any method as a single compiled
program; the 3-step peer-exchange cadence (paper Sec 4.3.1) is a
``lax.cond`` on the step index carried in ``info["t"]``.

Population churn: ``info["active"]`` ([M] bool, optional) marks which mules
are switched on this step. An inactive mule neither trains, delivers,
receives, nor serves as a gossip/oppcl peer — its model, timestamp, and
freshness records are carried bitwise (``apply_activity_mask`` selects old
leaves back in after the dense update). An all-ones mask reproduces the
dense path bitwise: masking enters only as ``& active`` on the delivery
mask and elementwise ``jnp.where`` selects, never as a change to the dense
computation itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.aggregation import batched_mix, masked_group_mean
from repro.core.freshness import FreshnessConfig, accept_mask, init_freshness, push_and_update

TrainFn = Callable[[Any, Any, jnp.ndarray], Any]   # (params, batch, key) -> params


@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    mode: str = "fixed"            # "fixed" | "mobile" — which side trains
    n_fixed: int = 8
    n_mules: int = 20
    gamma: float = 0.5             # aggregation mixing weight
    freshness: FreshnessConfig = FreshnessConfig()
    agg_backend: str = "ref"
    enc_backend: str = "ref"       # peer-encounter mix: ref | pallas | auto
    aggregation: str = "weighted"  # weighted | prox (FedProx-style damping)
    prox_mu: float = 0.1


def init_population(key, init_model_fn: Callable[[jnp.ndarray], Any],
                    cfg: PopulationConfig) -> Dict[str, Any]:
    km, kf = jax.random.split(key)
    mule_models = jax.vmap(init_model_fn)(jax.random.split(km, cfg.n_mules))
    fixed_models = jax.vmap(init_model_fn)(jax.random.split(kf, cfg.n_fixed))
    return {
        "mule_models": mule_models,
        "fixed_models": fixed_models,
        "mule_ts": jnp.zeros((cfg.n_mules,), jnp.float32),
        "fresh": init_freshness(cfg.n_fixed, cfg.freshness),
        "t": jnp.zeros((), jnp.float32),
    }


def apply_activity_mask(active, new: Any, old: Any) -> Any:
    """Per-leaf select: lane ``m`` takes ``new`` where ``active[m]``.

    ``active`` broadcasts against each leaf's leading (population) axis, so
    inactive lanes carry ``old`` bitwise; an all-ones mask returns ``new``
    bitwise (``jnp.where`` is an elementwise select of already-computed
    values — it never perturbs the dense update). ``active=None`` means no
    churn and returns ``new`` unchanged, so call sites need no guard.
    """
    if active is None:
        return new

    def sel(n, o):
        m = active.reshape(active.shape + (1,) * (n.ndim - active.ndim))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def population_step(state: Dict[str, Any], info: Dict[str, jnp.ndarray],
                    batches: Dict[str, Any], train_fn: TrainFn,
                    cfg: PopulationConfig, key) -> Dict[str, Any]:
    """One simulation time step.

    info:    {"fixed_id": [M] int32 (-1 = corridor), "exchange": [M] bool,
              "active": [M] bool (optional; absent == all active)}
    batches: {"fixed": [F, B, ...], "mule": [M, B, ...]} (per mode; a mode
             only reads the side that trains).

    An inactive mule (``~info["active"]``) delivers nothing, receives
    nothing, and (mobile mode) does not train — every per-mule effect of
    the protocol is already gated on ``deliver``, so folding the mask into
    it covers the whole cycle.
    """
    t = state["t"]
    fid = info["fixed_id"]
    deliver = info["exchange"] & (fid >= 0)
    if info.get("active") is not None:
        deliver = deliver & info["active"]

    # -- 1–2: deliver + freshness filter ------------------------------------
    ages = t - state["mule_ts"]
    fresh_ok = accept_mask(state["fresh"], fid, ages, cfg.freshness) & deliver

    # -- 3: dwell-weighted aggregation at fixed devices ----------------------
    assign = (jax.nn.one_hot(jnp.maximum(fid, 0), cfg.n_fixed, axis=0)
              * fresh_ok[None, :].astype(jnp.float32))          # [F, M]
    agg, mass = masked_group_mean(state["mule_models"], assign,
                                  backend=cfg.agg_backend)
    has = (mass > 0).astype(jnp.float32)
    gamma = cfg.gamma / (1.0 + cfg.prox_mu) if cfg.aggregation == "prox" \
        else cfg.gamma
    fixed_models = batched_mix(state["fixed_models"], agg, gamma * has)

    fresh = push_and_update(state["fresh"], fid, ages, deliver, cfg.freshness)

    # -- 4: training ----------------------------------------------------------
    if cfg.mode == "fixed":
        keys = jax.random.split(key, cfg.n_fixed)
        trained = jax.vmap(train_fn)(fixed_models, batches["fixed"], keys)
        fixed_models = batched_mix(fixed_models, trained, has)  # only active devices
    # -- 5: send back to mules ------------------------------------------------
    per_mule_fixed = jax.tree.map(lambda l: l[jnp.maximum(fid, 0)], fixed_models)
    gm = cfg.gamma * deliver.astype(jnp.float32)
    mule_models = batched_mix(state["mule_models"], per_mule_fixed, gm)

    if cfg.mode == "mobile":
        keys = jax.random.split(key, cfg.n_mules)
        trained = jax.vmap(train_fn)(mule_models, batches["mule"], keys)
        mule_models = batched_mix(mule_models, trained, deliver.astype(jnp.float32))

    mule_ts = jnp.where(deliver, t, state["mule_ts"])
    return {
        "mule_models": mule_models,
        "fixed_models": fixed_models,
        "mule_ts": mule_ts,
        "fresh": fresh,
        "t": t + 1.0,
    }


# ---------------------------------------------------------------------------
# method dispatch: every mobile-protocol method as one step signature
# ---------------------------------------------------------------------------

# The five methods of the paper's mobile-device experiments (Figs 6-9).
METHODS_MOBILE = ("mlmule", "gossip", "oppcl", "local", "mlmule+gossip")


def make_method_step(method: str, train_fn: TrainFn, cfg: PopulationConfig,
                     area: jnp.ndarray) -> Callable:
    """Build a traceable one-step update for any ``METHODS_MOBILE`` method.

    Thin wrapper: the method's semantics live in the one
    ``repro.core.method_program.METHOD_PROGRAMS`` table (cadences, key
    discipline, churn handling — see that module for the contract and the
    recipe for adding a method), and ``compile_step`` lowers the program to
    the single-host scan step. The returned function has the uniform
    signature ``step(state, info, batches, key) -> state`` where ``info``
    extends the ``population_step`` contract with ``"pos"`` ([M, 2] mule
    positions) and ``"t"`` (scalar int32 step index); ``area`` is the
    per-mule area vector the peer-encounter methods need (areas are
    isolated). Bitwise-pinned by the parity tests against
    ``run_population_loop``.
    """
    # deferred: method_program builds on repro.core + repro.baselines
    from repro.core.method_program import compile_step, get_program
    return compile_step(get_program(method), train_fn, cfg, area)


# ---------------------------------------------------------------------------
# evaluation helpers
# ---------------------------------------------------------------------------


def eval_population(models: Any, eval_fn: Callable[[Any, Any], jnp.ndarray],
                    test_data: Any) -> jnp.ndarray:
    """models: stacked [P, ...]; test_data: stacked [P, N, ...] -> metric [P]."""
    return jax.vmap(eval_fn)(models, test_data)
