"""ML Mule core: the paper's contribution as composable JAX modules.

- ``aggregation``  — dwell-weighted model averaging (population-scale masked
                     segment reduce; Pallas ``mule_agg`` kernel underneath).
- ``freshness``    — the dynamic staleness threshold
                     T <- (1-a)T + a(median(L) + b*MAD(L)).
- ``protocol``     — the In-House phase cycles (fixed-device training:
                     share-aggregate-train-share; mobile-device training:
                     share-aggregate-share-train) and the Mule phase.
- ``population``   — vectorized multi-device simulation engine (stacked
                     pytrees; jittable steps).
- ``distributed``  — shard_map population engine: mules sharded over the
                     ``data`` mesh axis, areas mapped to pods; the whole
                     replay scans inside one shard_map program
                     (``repro.scenarios.run_population_distributed``).
"""
from repro.core.aggregation import masked_group_mean, pairwise_mix, weighted_average  # noqa: F401
from repro.core.freshness import (  # noqa: F401
    FreshnessConfig, init_freshness, init_freshness_sketch, push_and_update,
    sketch_median_mad, sketch_push_and_update)
from repro.core.population import (  # noqa: F401
    METHODS_MOBILE, PopulationConfig, apply_activity_mask, init_population,
    make_method_step, population_step)
