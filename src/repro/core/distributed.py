"""Distributed ML Mule: the population engine under shard_map.

Mapping (DESIGN.md Sec 2):
- the mule population axis shards over the mesh ``data`` axis;
- physical areas map to pods (the paper's two near-isolated cities);
- fixed-device models are small and replicated; each shard computes its
  mules' aggregation *contributions* locally and a single ``psum`` combines
  them — the paper's many tiny peer-to-peer exchanges become one fused
  segment-reduce + all-reduce per step;
- the rare cross-area mule (0.715% in the Foursquare data) is a
  ``collective_permute`` of mule state across the ``pod`` axis.

Semantics note (documented deviation): the single-host engine keeps the
paper's exact median/MAD freshness statistics; this engine replaces them
with mean/std (associative, collective-friendly). Tests check the two
engines agree on aggregation results when the filter accepts everything.

Two collective schedules are provided (Perf hillclimb lever):
- ``cross_pod=True``  (baseline): F fixed devices replicated everywhere;
  contributions psum over ("pod", "data") — simple, but the [F, D] partial
  sums cross the pod boundary every step.
- ``cross_pod=False`` (optimized): fixed devices are pod-local (4 per pod);
  psum only over "data"; zero steady-state inter-pod traffic, matching the
  paper's observation that areas are nearly isolated.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.population import PopulationConfig


@dataclasses.dataclass(frozen=True)
class DistributedConfig:
    pop: PopulationConfig
    data_axis: str = "data"
    pod_axis: str = "pod"          # "" -> single-pod mesh
    cross_pod: bool = True         # collective schedule (see module docstring)
    ema_alpha: float = 0.1
    ema_beta: float = 1.0


def _tree_mix(a, b, gamma):
    def mix(x, y):
        g = jnp.reshape(gamma, gamma.shape + (1,) * (x.ndim - gamma.ndim))
        return (1.0 - g) * x + g * y
    return jax.tree.map(mix, a, b)


def make_distributed_step(train_fn: Callable, dcfg: DistributedConfig,
                          mesh: Mesh):
    """Builds a jitted distributed population step.

    State layout (shardings set by the caller via NamedSharding):
      mule_models [M, ...]   sharded P(data_axis)
      mule_ts     [M]        sharded P(data_axis)
      fixed_models [F, ...]  replicated
      threshold   [F]        replicated
      t           scalar     replicated
    info: fixed_id [M] int32, exchange [M] bool — sharded P(data_axis).
    batches: {"fixed": [F, B, ...] replicated, "mule": [M, B, ...] sharded}.
    """
    cfg = dcfg.pop
    axes = (dcfg.pod_axis, dcfg.data_axis) if dcfg.pod_axis else (dcfg.data_axis,)
    reduce_axes = axes if dcfg.cross_pod else (dcfg.data_axis,)
    mspec = P(dcfg.data_axis)     # population axis
    rspec = P()                    # replicated

    def step(mule_models, mule_ts, fixed_models, threshold, t,
             fixed_id, exchange, fixed_batches, mule_batches, key):
        deliver = exchange & (fixed_id >= 0)
        ages = t - mule_ts
        fresh_ok = deliver & (ages <= threshold[jnp.maximum(fixed_id, 0)])

        # -- local contributions + global reduce ----------------------------
        a_loc = (jax.nn.one_hot(jnp.maximum(fixed_id, 0), cfg.n_fixed, axis=0)
                 * fresh_ok[None, :].astype(jnp.float32))        # [F, M_loc]

        def seg_sum(leaf):
            flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
            return (a_loc @ flat).reshape((cfg.n_fixed,) + leaf.shape[1:])

        part = jax.tree.map(seg_sum, mule_models)
        counts = jnp.sum(a_loc, axis=1)
        part = jax.lax.psum(part, reduce_axes)
        counts = jax.lax.psum(counts, reduce_axes)
        has = (counts > 0).astype(jnp.float32)
        agg = jax.tree.map(
            lambda l: l / jnp.maximum(counts, 1.0).reshape(
                (-1,) + (1,) * (l.ndim - 1)), part)
        fixed_models = _tree_mix(fixed_models, agg, cfg.gamma * has)

        # -- freshness threshold: EMA of (mean + beta*std) of delivered ages --
        age_sum = jax.lax.psum(
            jnp.sum(a_loc * ages[None, :], axis=1), reduce_axes)
        age_sq = jax.lax.psum(
            jnp.sum(a_loc * (ages ** 2)[None, :], axis=1), reduce_axes)
        mean_age = age_sum / jnp.maximum(counts, 1.0)
        var_age = jnp.maximum(age_sq / jnp.maximum(counts, 1.0) - mean_age ** 2, 0.0)
        target = mean_age + dcfg.ema_beta * jnp.sqrt(var_age)
        threshold = jnp.where(
            counts > 0,
            (1 - dcfg.ema_alpha) * threshold + dcfg.ema_alpha * target,
            threshold)

        # -- training (replicated for fixed mode; shard-local for mobile) ----
        if cfg.mode == "fixed":
            keys = jax.random.split(key, cfg.n_fixed)
            trained = jax.vmap(train_fn)(fixed_models, fixed_batches, keys)
            fixed_models = _tree_mix(fixed_models, trained, has)

        per_mule_fixed = jax.tree.map(
            lambda l: l[jnp.maximum(fixed_id, 0)], fixed_models)
        gm = cfg.gamma * deliver.astype(jnp.float32)
        mule_models = _tree_mix(mule_models, per_mule_fixed, gm)

        if cfg.mode == "mobile":
            m_loc = fixed_id.shape[0]
            shard_key = jax.random.fold_in(
                key, jax.lax.axis_index(dcfg.data_axis))
            keys = jax.random.split(shard_key, m_loc)
            trained = jax.vmap(train_fn)(mule_models, mule_batches, keys)
            mule_models = _tree_mix(mule_models, trained,
                                    deliver.astype(jnp.float32))

        mule_ts = jnp.where(deliver, t, mule_ts)
        return mule_models, mule_ts, fixed_models, threshold, t + 1.0

    sharded = shard_map(
        step, mesh=mesh,
        in_specs=(mspec, mspec, rspec, rspec, rspec,
                  mspec, mspec, rspec, mspec, rspec),
        out_specs=(mspec, mspec, rspec, rspec, rspec),
        check_rep=False)
    return jax.jit(sharded)


def migrate_mules(mule_models: Any, move_mask: jnp.ndarray, mesh: Mesh,
                  pod_axis: str = "pod", data_axis: str = "data"):
    """Cross-area mule transport: swap flagged mule slots with the next pod.

    move_mask: [M] bool (sharded over data). A flagged mule's model is sent
    to the same slot on the next pod (ring collective_permute) — the paper's
    inter-city traveler.
    """
    n_pods = mesh.shape[pod_axis]
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

    def swap(models, mask):
        def one(leaf):
            recv = jax.lax.ppermute(leaf, pod_axis, perm)
            m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.where(m, recv, leaf)
        return jax.tree.map(one, models)

    sharded = shard_map(
        swap, mesh=mesh,
        in_specs=(P(data_axis), P(data_axis)),
        out_specs=P(data_axis),
        check_rep=False)
    return sharded(mule_models, move_mask)
