"""Distributed ML Mule: the population engine under shard_map.

Mapping (DESIGN.md Sec 2):
- the mule population axis shards over the mesh ``data`` axis;
- physical areas map to pods (the paper's two near-isolated cities);
- fixed-device models are small and replicated; each shard computes its
  mules' aggregation *contributions* locally and a single ``psum`` combines
  them — the paper's many tiny peer-to-peer exchanges become one fused
  segment-reduce + all-reduce per step;
- the rare cross-area mule (0.715% in the Foursquare data) is a
  ``collective_permute`` of mule state across the ``pod`` axis.

``make_distributed_method_step`` builds the shard-local one-step update the
*scan* engine replays: same ``(state, info, batches, key) -> state``
signature as the single-host ``make_method_step``, both thin wrappers over
the one ``repro.core.method_program`` table. ML Mule's space exchange
lowers to the fused segment-reduce + psum collective schedule; the
peer-encounter baselines (gossip/oppcl/mlmule+gossip) lower to a ring
``ppermute`` exchange that streams population blocks around the mesh mule
axis — so every ``METHODS_MOBILE`` method shards. The whole replay —
collectives included — then runs as one ``lax.scan`` under ``shard_map``
(``repro.scenarios.run_population_distributed``), so an experiment is a
single XLA program instead of thousands of per-step dispatches.  The old
per-step ``make_distributed_step`` — a dense one-hot segment-reduce per
model leaf — has been deleted outright: the fused ``encounter_mix``
schedule (Pallas-tiled on TPU, its bitwise reference elsewhere) is the
*only* encounter path on the distributed engines, and the per-step
dispatch baseline the benchmarks time is the scan engine driven one
chunk per step (``run_population_distributed_loop``).

Freshness semantics: the scan engine closes the formerly documented
mean/std deviation — with ``FreshnessConfig.stat == "median"`` (default)
delivered ages feed an associative histogram sketch whose per-step shard
contributions merge under the same psum as the aggregation, recovering the
paper's Sec 3.1 median/MAD to bin accuracy (``repro.core.freshness``).
``stat == "meanstd"`` keeps the legacy per-step mean/std EMA, reading
alpha/beta from ``FreshnessConfig`` like every other engine path.

Multi-process: every collective here is also run under ``jax.distributed``
(``launch.multiprocess`` bring-up, gloo CPU backend in tests/benches).
Float cross-shard reductions go through ``ordered_psum`` — gloo and
single-process XLA reduce in different orders, and an unordered ``psum``
would drift ULPs off the pinned cross-topology bitwise parity. Integer
reductions (counts, ring need-masks, the re-bucketing area gather) are
exact under any order and stay on ``lax.psum``.

Two collective schedules are provided (Perf hillclimb lever):
- ``cross_pod=True``  (baseline): F fixed devices replicated everywhere;
  contributions psum over ("pod", "data") — simple, but the [F, D] partial
  sums cross the pod boundary every step.
- ``cross_pod=False`` (optimized): fixed devices are pod-local (4 per pod);
  psum only over "data"; zero steady-state inter-pod traffic, matching the
  paper's observation that areas are nearly isolated.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.freshness import (FreshnessConfig, age_histogram,
                                  init_freshness_sketch)
from repro.core.population import PopulationConfig


@dataclasses.dataclass(frozen=True)
class DistributedConfig:
    pop: PopulationConfig
    data_axis: str = "data"
    pod_axis: str = "pod"          # "" -> single-pod mesh
    cross_pod: bool = True         # collective schedule (see module docstring)
    # area-bitmask hop pruning of the peer-exchange ring (exact — a pruned
    # hop would contribute nothing; False measures the dense ring)
    ring_prune: bool = True
    # ring area-bitmask width. 0 = auto: 32 bits, widened to 64 when the
    # run's max area id needs it (>32 areas alias under a 32-bit fold and
    # quietly stop pruning). The drivers resolve 0 to a concrete width
    # before the value enters any jit cache key.
    ring_bits: int = 0
    # mid-run re-bucketing: every `rebucket_every` steps (chunk-aligned on
    # the streamed engine) the compiled replay emits the psum'd fraction of
    # mules whose current area drifted off their bucket area; when it
    # crosses `rebucket_threshold` the driver recomputes the bucket order
    # and permutes the full live mule state + in-flight colocation columns
    # through the mesh. 0 = off (build-time bucketing only, PR 7 behavior).
    rebucket_every: int = 0
    rebucket_threshold: float = 0.25


def _tree_mix(a, b, gamma):
    def mix(x, y):
        g = jnp.reshape(gamma, gamma.shape + (1,) * (x.ndim - gamma.ndim))
        return (1.0 - g) * x + g * y
    return jax.tree.map(mix, a, b)


def ordered_psum(x, axis_name):
    """Order-deterministic float ``psum``: all_gather + rank-order fold.

    ``lax.psum`` leaves the float reduction order to the backend — XLA's
    single-process all-reduce and the gloo cross-process one disagree,
    so a raw psum breaks the engines' cross-topology bitwise pins (the
    same run over 1 or N processes). ``all_gather`` is pure data
    movement (bitwise-safe on both), and a left-to-right fold over the
    gathered shards fixes the reduction order as a function of the mesh
    axis alone. Axis sizes here are ring-scale, so the serial fold is
    free next to the payload it reduces. Integer reductions are exact
    under any order — keep those on ``lax.psum``.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    g = jax.lax.all_gather(x, axes, axis=0, tiled=False)
    return jax.tree.map(
        lambda l: functools.reduce(
            lambda a, b: a + b, [l[i] for i in range(l.shape[0])]), g)


def ordered_pmean(x, axis_name):
    """``ordered_psum`` divided by the axis size — deterministic pmean."""
    s = ordered_psum(x, axis_name)
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n = 1
    for ax in axes:
        n = n * jax.lax.psum(1, ax)
    return jax.tree.map(lambda l: l / n, s)


@functools.lru_cache(maxsize=8)
def _bucket_order_program(mesh: Mesh, data_axis: str, n_shards: int,
                          m_loc: int):
    """Compiled replicated stable argsort of the sharded area vector."""
    def order_fn(a_loc):
        i = jax.lax.axis_index(data_axis)
        placed = jax.lax.dynamic_update_slice(
            jnp.zeros((n_shards * m_loc,), jnp.int32),
            a_loc.astype(jnp.int32), (i * m_loc,))
        full = jax.lax.psum(placed, data_axis)        # int32: exact
        order = jnp.argsort(full, stable=True).astype(jnp.int32)
        return order, full

    return jax.jit(shard_map(
        order_fn, mesh=mesh, in_specs=(P(data_axis),),
        out_specs=(P(), P()), check_rep=False))


def global_bucket_order(area_last, mesh, data_axis: str = "data"):
    """Multi-host-safe bucket order of the current (sharded) area vector.

    The PR 9 drift swap argsorted ``np.asarray(area_last)`` on the host —
    fine while one process owned the whole [M] vector, impossible once it
    shards across processes. Here every shard contributes its block
    through an exact integer psum (dynamic placement into the zeroed
    global vector), each process argsorts the identical replicated copy
    inside the compiled program, and the replicated ``(order, area)``
    pair comes back readable on every process. Stable argsort matches
    ``np.argsort(kind="stable")`` exactly, so single-process rebucketing
    decisions (and their bitwise pins) are unchanged.
    """
    m = int(area_last.shape[0])
    n_shards = int(mesh.shape[data_axis])
    fn = _bucket_order_program(mesh, data_axis, n_shards, m // n_shards)
    return fn(area_last)


def init_distributed_freshness(n_fixed: int, cfg: FreshnessConfig):
    """Replicated freshness state for the scan engine, per ``cfg.stat``."""
    if cfg.stat == "median":
        return init_freshness_sketch(n_fixed, cfg)
    if cfg.stat == "meanstd":
        return {"threshold": jnp.full((n_fixed,), cfg.init_threshold,
                                      jnp.float32)}
    raise ValueError(f"unknown freshness stat {cfg.stat!r}; "
                     "expected 'median' or 'meanstd'")


def to_distributed_state(state: Dict[str, Any],
                         dcfg: DistributedConfig) -> Dict[str, Any]:
    """Convert an ``init_population`` state for the distributed engine.

    Swaps the exact ring-buffer freshness state for the collective-friendly
    variant ``dcfg.pop.freshness.stat`` selects, carrying the learned
    threshold over and (for the sketch) binning the ring's resident ages so
    no history is lost at the handoff.
    """
    cfg = dcfg.pop.freshness
    fresh = init_distributed_freshness(dcfg.pop.n_fixed, cfg)
    old = state.get("fresh", {})
    if "threshold" in old:
        fresh["threshold"] = old["threshold"]
    if cfg.stat == "median" and "ages" in old:
        valid = old["ages"] < 1e29
        fresh["hist"] = age_histogram(old["ages"],
                                      valid.astype(jnp.float32), cfg)
        fresh["count"] = old["count"]
    return {**state, "fresh": fresh}


def make_distributed_method_step(method: str, train_fn: Callable,
                                 dcfg: DistributedConfig,
                                 mesh: Mesh = None) -> Callable:
    """Shard-local one-step update for the distributed scan engine.

    Thin wrapper over the one ``repro.core.method_program`` table (the
    same programs ``make_method_step`` lowers single-host), compiled to the
    shard_map lowering: same ``step(state, info, batches, key) -> state``
    signature, but every array with a leading mule axis is the *local
    shard* of the population ([M_loc, ...], M_loc = n_mules / data-axis
    size), ``info`` additionally carries the shard-local ``"area"`` block,
    and the step must run inside ``shard_map`` over ``dcfg.data_axis``.
    ``state`` follows the ``to_distributed_state`` layout: mule_models /
    mule_ts sharded, fixed_models/fresh/t replicated.

    All five ``METHODS_MOBILE`` lower: ``mlmule`` runs the fused
    segment-reduce + single-psum collective schedule; the peer-encounter
    baselines (gossip / oppcl / the mlmule+gossip hybrid) stream each
    shard's (pos, area, active, payload) block around the mesh mule axis
    with a ring ``ppermute`` (``mesh`` is required to size the ring);
    ``local`` needs no collective at all.

    Key discipline mirrors the single-host engine exactly: fixed-mode
    training splits the replicated key over ``n_fixed``; every per-mule
    draw splits it over the *global* ``n_mules`` and slices the local
    block, so per-mule draws are identical to a single-host run regardless
    of shard count. Mule batches produced replicated (a batch callable
    returning full ``[n_mules, ...]`` arrays) are sliced the same way;
    batches already shard-local (stacked sharded inputs) pass through.

    Churn: ``info["active"]`` ([M_loc] bool, sharded like ``fixed_id``)
    masks switched-off mules with the single-host semantics — mlmule ANDs
    it into the delivery mask before the fused reduction, peer exchanges
    drop inactive mules from both sides of the streamed encounter test,
    and local/mobile training where-selects old models back in.
    """
    from repro.core.method_program import (compile_distributed_step,
                                           get_program)
    ring_size = (int(mesh.shape[dcfg.data_axis]) if mesh is not None
                 else None)
    return compile_distributed_step(get_program(method), train_fn, dcfg,
                                    ring_size=ring_size)


def migrate_mules(mule_models: Any, move_mask: jnp.ndarray, mesh: Mesh,
                  pod_axis: str = "pod", data_axis: str = "data"):
    """Cross-area mule transport: swap flagged mule slots with the next pod.

    move_mask: [M] bool (sharded over data). A flagged mule's model is sent
    to the same slot on the next pod (ring collective_permute) — the paper's
    inter-city traveler (0.715% of Foursquare check-ins). Applying the swap
    ``n_pods`` times walks a slot around the whole ring back to its origin,
    so migrations round-trip bitwise (pinned by ``tests/test_distributed``);
    ``migrate_mule_state`` lifts this to the full live-state pytree; the
    scan drivers fire it between chunks when ``rebucket_every`` is set.
    """
    n_pods = mesh.shape[pod_axis]
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

    def swap(models, mask):
        def one(leaf):
            recv = jax.lax.ppermute(leaf, pod_axis, perm)
            m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.where(m, recv, leaf)
        return jax.tree.map(one, models)

    sharded = shard_map(
        swap, mesh=mesh,
        in_specs=(P(data_axis), P(data_axis)),
        out_specs=P(data_axis),
        check_rep=False)
    return sharded(mule_models, move_mask)


def migrate_mule_state(state: Dict[str, Any], move_mask: jnp.ndarray,
                       mesh: Mesh, pod_axis: str = "pod",
                       data_axis: str = "data") -> Dict[str, Any]:
    """``migrate_mules`` over the *full* live-state pytree.

    ``migrate_mules`` only ever saw model leaves; mid-run re-bucketing has
    to move everything a mule owns — models, delivery timestamps, freshness
    carry, optimizer slots — or the swapped-in mule trains against a
    stranger's history. Every sharded ``mule*`` leaf rides the same pod-ring
    ``collective_permute``; replicated leaves (fixed models, freshness
    sketch, scalar clock) pass through untouched. Applying the swap
    ``n_pods`` times round-trips bitwise, same as the model-only primitive.
    """
    moving = {k: v for k, v in state.items()
              if k.startswith("mule") and v is not None}
    if not moving:
        return dict(state)
    swapped = migrate_mules(moving, move_mask, mesh,
                            pod_axis=pod_axis, data_axis=data_axis)
    return {**state, **swapped}


_row_gather = jax.jit(lambda l, o: l[jnp.asarray(o)])


def bucket_mule_order(area) -> np.ndarray:
    """Area ids -> [M] permutation grouping mules by spatial bucket.

    Accepts the static [M] contract or a time-varying [T, M] trace (the
    mobility scenarios that motivate re-bucketing) — build-time bucketing
    uses the t=0 row; the re-bucketing drivers pass the current row
    explicitly. Stable sort, so the order within a bucket (and the
    identity when every mule shares one area) is preserved. Applying this
    at colocation build time makes the population's shard blocks
    area-contiguous, which is what lets the ring's area-bitmask predicate
    prune remote hops — interleaved assignments leave every area on every
    shard and nothing prunable. Mid-run, the scan drivers re-apply it
    whenever the compiled replay's drift scalar crosses
    ``DistributedConfig.rebucket_threshold``.
    """
    a = np.asarray(area)
    if a.ndim == 2:
        a = a[0]
    return np.argsort(a, kind="stable")


def reorder_colocation(colocation: Dict[str, Any],
                       order: np.ndarray) -> Dict[str, Any]:
    """Apply a mule permutation to every per-mule colocation column.

    Works on any colocation dict whose values are [T, M] (fixed_id /
    exchange / active / time-varying area / pos [T, M, 2]) or [M] (static
    area) arrays; the mule axis is the one matching ``len(order)``.
    """
    order = np.asarray(order)

    def one(v):
        a = np.asarray(v)
        if a.ndim >= 2 and a.shape[1] == order.shape[0]:
            return a[:, order]
        if a.ndim >= 1 and a.shape[0] == order.shape[0]:
            return a[order]
        return a
    return {k: one(v) for k, v in colocation.items()}


def reorder_mule_state(state: Dict[str, Any], order) -> Dict[str, Any]:
    """Apply a mule permutation to the per-mule state leaves.

    Every ``mule*`` entry — models, timestamps, and any future per-mule
    carry (freshness, optimizer slots) — has its rows follow their
    colocation columns (``reorder_colocation``), so a bucket-ordered run is
    the same simulation with mules renumbered; replicated leaves pass
    through. Mid-run re-bucketing relies on this covering the *full* live
    state: a key it missed would silently cross-wire a mule's history.
    The gather runs jitted so it also applies to state sharded across
    processes (eager gathers reject multi-host arrays); on one process
    the jitted gather is bitwise the old eager one.
    """
    order = np.asarray(order)
    out = dict(state)
    for k in out:
        if k.startswith("mule") and out[k] is not None:
            out[k] = jax.tree.map(lambda l: _row_gather(l, order), out[k])
    return out


def bucket_locality_fraction(area, n_shards: int) -> float:
    """Fraction of same-area ordered mule pairs that are shard-local under
    the equal-block layout of ``area`` over ``n_shards`` shards.

    Same-area pairs are exactly the candidate encounters the ring must
    cover, so this is the share of encounter work the shard-local hop can
    serve — the benchmark's bucket-locality telemetry. 1.0 when there are
    no same-area pairs at all. Shards are the ``np.array_split`` blocks, so
    a population size that does not divide ``n_shards`` is handled exactly
    (the old equal-block slicing silently dropped the ragged tail, counting
    its pairs as neither local nor remote).
    """
    a = np.asarray(area)
    if a.ndim == 2:
        a = a[0]
    local = total = 0
    blocks = np.array_split(a, n_shards)
    for u in np.unique(a):
        c = int((a == u).sum())
        total += c * (c - 1)
        for blk in blocks:
            ck = int((blk == u).sum())
            local += ck * (ck - 1)
    return float(local) / float(total) if total else 1.0
