from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, clip_by_global_norm, cosine_schedule, linear_schedule,
    sgd,
)
