"""Minimal pytree optimizers (no external deps): SGD(+momentum), Adam, AdamW.

API mirrors the usual (init, update) pair:
    opt = sgd(lr=0.1, momentum=0.9)
    state = opt.init(params)
    params, state = opt.update(params, grads, state)
``lr`` may be a float or a schedule fn step -> float; state carries the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
LR = Union[float, Schedule]


def _lr_at(lr: LR, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def sgd(lr: LR = 0.01, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            if nesterov:
                upd = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
            else:
                upd = mu
            new_state = {"step": step, "mu": mu}
        else:
            upd = grads
            new_state = {"step": step}
        params = jax.tree.map(lambda p, u: p - lr_t * u.astype(p.dtype), params, upd)
        return params, new_state

    return Optimizer(init, update)


def adam(lr: LR = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            u = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return p - lr_t * u.astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr: LR = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


# ---------------------------------------------------------------------------
# schedules / utilities
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.0) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * (final_frac + (1 - final_frac) * cos)

    return fn


def linear_schedule(base_lr: float, total_steps: int, warmup: int = 0) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        return base_lr * warm * (1 - prog)

    return fn


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm
