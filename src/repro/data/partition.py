"""Data partitioning: IID, Dirichlet, and the paper's Shards scheme.

All partitioners return index arrays per device/space; the caller gathers the
underlying arrays. Matches the paper's setups:

- ``dirichlet_partition`` — Hsu et al. [13]: per-device class mixture drawn
  from Dir(alpha). (The paper's Fig. 5 uses alpha in {0.001, 0.01, 0.1}; as
  in the paper's text, *smaller* alpha concentrates fewer classes per space.)
- ``shards_partition`` — FedAvg-style shards adapted per Sec 4.3.1: the 20
  super-classes are split 10/10 between Area 0 and Area 1; within an area
  each of the 4 spaces holds exactly one sub-class of each super-class, and
  each device additionally receives the (unassigned) 5th sub-class as
  general knowledge.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(labels: np.ndarray, n_parts: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(p) for p in np.array_split(idx, n_parts)]


def dirichlet_partition(labels: np.ndarray, n_parts: int, alpha: float,
                        seed: int = 0, min_per_part: int = 8) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    parts: List[List[int]] = [[] for _ in range(n_parts)]
    for c in range(n_classes):
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        while True:  # resample until no part is starved to zero by rounding
            props = rng.dirichlet([alpha] * n_parts)
            if props.max() < 1.0 - 1e-12:
                break
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for p, chunk in enumerate(np.split(idx_c, cuts)):
            parts[p].extend(chunk.tolist())
    out = []
    pool = np.arange(len(labels))
    for p in range(n_parts):
        arr = np.array(sorted(parts[p]), dtype=np.int64)
        if len(arr) < min_per_part:  # top up starved parts with random samples
            extra = rng.choice(pool, size=min_per_part - len(arr), replace=False)
            arr = np.sort(np.concatenate([arr, extra]))
        out.append(arr)
    return out


def shards_partition(super_labels: np.ndarray, sub_labels: np.ndarray,
                     n_areas: int = 2, n_spaces_per_area: int = 4,
                     n_sub: int = 5, seed: int = 0) -> Dict:
    """The paper's adapted Shards scheme (Sec 4.3.1).

    Returns dict with:
      space_idx[(area, space)]  -> indices matching that space's distribution
      general_idx[(area, space)] -> indices of the 5th (held-out) sub-class
                                    for the supers of that area
    """
    rng = np.random.default_rng(seed)
    n_super = int(super_labels.max()) + 1
    supers = rng.permutation(n_super)
    area_supers = np.array_split(supers, n_areas)

    space_idx, general_idx = {}, {}
    for a in range(n_areas):
        # assign one sub-class (0..3) of each super to each space; sub 4 is general
        for sp in range(n_spaces_per_area):
            sel = np.zeros(len(super_labels), bool)
            gen = np.zeros(len(super_labels), bool)
            for s in area_supers[a]:
                sub_of = sub_labels - s * n_sub
                in_super = super_labels == s
                sel |= in_super & (sub_of == sp)
                gen |= in_super & (sub_of == n_sub - 1)
            space_idx[(a, sp)] = np.where(sel)[0]
            general_idx[(a, sp)] = np.where(gen)[0]
    return {"space_idx": space_idx, "general_idx": general_idx,
            "area_supers": [s.tolist() for s in area_supers]}


def train_test_split(idx: np.ndarray, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(idx)
    n_test = max(1, int(len(idx) * test_frac))
    return idx[n_test:], idx[:n_test]
