from repro.data.partition import (  # noqa: F401
    dirichlet_partition, iid_partition, shards_partition)
from repro.data.synthetic import (  # noqa: F401
    make_image_dataset, make_imu_dataset, make_lm_dataset)
