"""Procedural datasets standing in for CIFAR-100 / EgoExo4D (data gate).

The real datasets are not available offline; these generators reproduce the
*structure* the paper's experiments depend on:

- ``make_image_dataset`` — hierarchical 20 super-classes × 5 sub-classes.
  Each super-class has a smooth spatial prototype; each sub-class adds a
  distinct offset pattern; samples add noise + random shifts. A small CNN can
  learn super-class classification, and the sub-class structure supports the
  paper's Shards partitioning (sub-classes split across spaces).
- ``make_imu_dataset`` — per-activity multi-sinusoid signatures over a 6-axis
  50 Hz window, with per-location sensor bias/gain domain shift mirroring
  EgoExo4D's location-conditioned activity distribution (Table 2).
- ``make_lm_dataset`` — token streams with per-space n-gram statistics (used
  by the large-arch examples).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _smooth_noise(rng: np.random.Generator, size: int, scale: int) -> np.ndarray:
    """Low-frequency pattern via upsampled coarse noise."""
    coarse = rng.normal(size=(scale, scale, 3))
    reps = size // scale
    return np.kron(coarse, np.ones((reps, reps, 1)))


def make_image_dataset(seed: int, n_per_sub: int = 200, n_super: int = 20,
                       n_sub: int = 5, size: int = 32, noise: float = 0.35
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (images [N,H,W,3] float32, super_labels [N], sub_labels [N]).

    sub_labels are globally unique: sub_id = super * n_sub + sub.
    ``noise`` controls sample difficulty (higher -> local overfitting regime,
    where collaboration pays off — the paper's operating point).
    """
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_noise(rng, size, 4) for _ in range(n_super)])
    sub_offsets = np.stack(
        [[_smooth_noise(rng, size, 8) * 0.6 for _ in range(n_sub)]
         for _ in range(n_super)])
    imgs, sup, sub = [], [], []
    for s in range(n_super):
        for c in range(n_sub):
            base = protos[s] + sub_offsets[s][c]
            noise_arr = rng.normal(scale=noise, size=(n_per_sub, size, size, 3))
            shift = rng.integers(-2, 3, size=(n_per_sub, 2))
            batch = base[None] + noise_arr
            for i in range(n_per_sub):  # small random translations
                batch[i] = np.roll(batch[i], tuple(shift[i]), axis=(0, 1))
            imgs.append(batch)
            sup.append(np.full(n_per_sub, s))
            sub.append(np.full(n_per_sub, s * n_sub + c))
    x = np.concatenate(imgs).astype(np.float32)
    x = (x - x.mean()) / (x.std() + 1e-6)
    return x, np.concatenate(sup).astype(np.int32), np.concatenate(sub).astype(np.int32)


def make_imu_dataset(seed: int, n_per_cell: int = 60, window: int = 128,
                     channels: int = 6, n_classes: int = 4, n_locations: int = 8,
                     density: np.ndarray | None = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (windows [N,T,C], labels [N], locations [N]).

    ``density`` (optional [n_classes, n_locations] of {0,1} or counts) mirrors
    the paper's Table 2: which activities occur at which locations. Default
    reproduces its sparsity pattern (several zero cells).
    """
    rng = np.random.default_rng(seed)
    if density is None:
        # Paper Table 2 (rows: Bike Repair, Cooking, Dance, Music) presence:
        density = np.array([
            [1, 1, 1, 0, 1, 0, 0, 0],
            [0, 1, 1, 1, 1, 1, 1, 1],
            [0, 0, 0, 0, 0, 0, 1, 1],
            [0, 0, 0, 1, 1, 0, 0, 1],
        ], dtype=np.float64)[:n_classes, :n_locations]
    t = np.arange(window) / 50.0  # 50 Hz
    base_freqs = rng.uniform(0.5, 8.0, size=(n_classes, channels, 3))
    base_amps = rng.uniform(0.3, 1.2, size=(n_classes, channels, 3))
    loc_bias = rng.normal(scale=0.25, size=(n_locations, channels))
    loc_gain = 1.0 + rng.normal(scale=0.12, size=(n_locations, channels))

    xs, ys, locs = [], [], []
    for c in range(n_classes):
        for l in range(n_locations):
            if density[c, l] == 0:
                continue
            n = int(n_per_cell * max(density[c, l], 1))
            phase = rng.uniform(0, 2 * np.pi, size=(n, channels, 3))
            sig = np.zeros((n, window, channels))
            for k in range(3):
                sig += (base_amps[c, :, k][None, None]
                        * np.sin(2 * np.pi * base_freqs[c, :, k][None, None] * t[None, :, None]
                                 + phase[:, None, :, k]))
            sig = sig * loc_gain[l][None, None] + loc_bias[l][None, None]
            sig += rng.normal(scale=0.4, size=sig.shape)
            xs.append(sig)
            ys.append(np.full(n, c))
            locs.append(np.full(n, l))
    x = np.concatenate(xs).astype(np.float32)
    return x, np.concatenate(ys).astype(np.int32), np.concatenate(locs).astype(np.int32)


def make_lm_dataset(seed: int, n_seqs: int, seq_len: int, vocab: int,
                    n_spaces: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Markov-chain token streams with per-space transition statistics."""
    rng = np.random.default_rng(seed)
    seqs = np.zeros((n_seqs, seq_len), np.int32)
    spaces = rng.integers(0, n_spaces, size=n_seqs).astype(np.int32)
    # per-space sparse preferred-next tables
    nxt = rng.integers(0, vocab, size=(n_spaces, vocab, 4))
    for i in range(n_seqs):
        s = spaces[i]
        tok = rng.integers(0, vocab)
        for j in range(seq_len):
            seqs[i, j] = tok
            if rng.random() < 0.8:
                tok = nxt[s, tok, rng.integers(0, 4)]
            else:
                tok = rng.integers(0, vocab)
    return seqs, spaces
