"""Scenario subsystem: registry-driven workloads on a compiled scan engine.

    from repro.scenarios import get_scenario, run_population, run_sweep

    spec = get_scenario("commuter")          # or any of list_scenarios()
    co = spec.colocation(seed=0, n_mules=20, n_steps=500)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                eval_every=100, eval_fn=eval_hook,
                                method="gossip")    # any METHODS_MOBILE

Replays are jit-cached (``engine.jit_cache_stats``) and multi-seed sweeps
vmap into one compiled program (``sweep.run_sweep``). Scenarios with a
``ChurnSpec`` emit an ``"active"`` [T, M] mask in their colocation dict —
the engine threads it through every path (single-host, sweep, distributed)
so inactive mules neither train nor exchange; ``SpaceSpec`` tuples give
spaces heterogeneous exchange tempos. ``run_population_streamed`` +
``scenario_generator`` replay any registered scenario *without* the
``[T, M]`` schedule — colocation is generated chunk-by-chunk inside the
compiled scan (O(chunk·M) memory, bitwise-equal to the materialized path).
"""
from repro.scenarios.engine import (  # noqa: F401
    jit_cache_clear, jit_cache_stats, run_population,
    run_population_distributed, run_population_distributed_loop,
    run_population_loop, run_population_streamed)
from repro.scenarios.registry import (  # noqa: F401
    SCENARIOS, ChurnSpec, ScenarioSpec, SpaceSpec, get_scenario,
    list_scenarios, register, scenario_generator, trace_colocation,
    walk_colocation)
from repro.scenarios.sweep import (  # noqa: F401
    run_sweep, run_sweep_distributed, stack_colocations, stack_trees)
