"""Scenario subsystem: registry-driven workloads on a compiled scan engine.

    from repro.scenarios import get_scenario, run_population

    spec = get_scenario("commuter")          # or any of list_scenarios()
    co = spec.colocation(seed=0, n_mules=20, n_steps=500)
    final, aux = run_population(pop, co, batch_fn, train_fn, pcfg, key,
                                eval_every=100, eval_fn=eval_hook)
"""
from repro.scenarios.engine import run_population  # noqa: F401
from repro.scenarios.registry import (  # noqa: F401
    SCENARIOS, ScenarioSpec, get_scenario, list_scenarios, register,
    trace_colocation, walk_colocation)
