"""Compiled scenario engine: method-dispatched scans with a jit cache.

The harness used to drive the simulation with a per-step Python loop — one
jitted dispatch per time step, thousands of dispatches per experiment. Here
the whole run is one (optionally chunked) ``lax.scan`` over precomputed
``[T, M]`` co-location tensors, with periodic evaluation *inside* the scan,
so a full scenario replay is a single XLA program. Every mobile-protocol
method (``repro.core.population.METHODS_MOBILE``) rides the same engine:
``method=`` selects the per-step update built by ``make_method_step`` (the
baselines' 3-step exchange cadence is a ``lax.cond`` on the step index).

Jit cache
---------
``run_population`` used to retrace on every call — fine for one replay per
experiment, wasteful for sweeps. Compiled replays are now memoized in a
module-level cache keyed on everything that determines the traced program:

  (kind, method, cfg, eval_every, n_steps,
   train_fn, eval_fn, batch-callable identity,
   shape/dtype signatures of state, colocation tensors, stacked batches,
   context, and the PRNG key)

``cfg`` hashes by value (frozen dataclass); functions hash by identity, so
reuse the *same* ``train_fn``/``batches``/``eval_fn`` objects across calls
to hit the cache (a fresh lambda per call means a fresh trace). The cache
holds strong references but is LRU-bounded (oldest entries evicted past
``_JIT_CACHE_MAX``), so loops that can never hit — e.g. a fresh closure
per experiment — don't accumulate executables and closure-captured data
for process lifetime; ``jit_cache_clear()`` resets it and
``jit_cache_stats()`` reports ``{"traces", "hits", "misses"}`` — the
traces counter increments only when XLA actually retraces, which is what
``benchmarks/engine_micro.py`` asserts goes to zero on repeat calls.

Key discipline (the parity tests rely on reproducing it exactly):

- step ``t`` uses ``k_t = jax.random.fold_in(key, t)``;
- if ``batches`` is a callable ``(key, t) -> batches-dict`` (or
  ``(key, t, context) -> batches-dict`` when a ``context`` pytree is
  passed), the step splits ``kb, ks = jax.random.split(k_t)`` and calls
  ``batches(kb, t[, context])``; the training key is ``ks``;
- if ``batches`` is a pytree of stacked ``[T, ...]`` leaves, step ``t``
  consumes slice ``t`` and trains with ``k_t`` directly.

``run_population_loop`` preserves the retired per-step driver verbatim as
the parity reference (the same role ``trace_to_colocation_loop`` plays for
the vectorized trace expansion): Python-level method dispatch, one jitted
call per step. Tests pin scan-vs-loop bitwise equality per method.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from collections import OrderedDict

from repro.core.population import (PopulationConfig, TrainFn,
                                   make_method_step, population_step)

# LRU-bounded: callers that build fresh batch/eval closures per experiment
# (their identity is part of the key) can never hit, so eviction caps the
# executables + closure-captured datasets such loops would otherwise leak.
_JIT_CACHE: "OrderedDict[Any, Callable]" = OrderedDict()
_JIT_CACHE_MAX = 32
_STATS = {"traces": 0, "hits": 0, "misses": 0}


def jit_cache_stats() -> Dict[str, int]:
    """Snapshot of engine cache counters (traces/hits/misses)."""
    return dict(_STATS)


def jit_cache_clear() -> None:
    """Drop all memoized replays and reset the counters."""
    _JIT_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


def _sig(tree: Any) -> Any:
    """Hashable shape/dtype signature of a pytree (structure included)."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef,) + tuple(
        (tuple(np.shape(l)), np.dtype(jnp.result_type(l)).str) for l in leaves)


def _colocation_tensors(colocation, n_steps=None):
    """Normalize a colocation dict to (fid, exch, pos, area) jnp arrays."""
    fid = jnp.asarray(np.asarray(colocation["fixed_id"]), jnp.int32)
    exch = jnp.asarray(np.asarray(colocation["exchange"]), bool)
    t, m = fid.shape[-2], fid.shape[-1]
    pos = colocation.get("pos")
    pos = (jnp.zeros(fid.shape + (2,), jnp.float32) if pos is None
           else jnp.asarray(np.asarray(pos), jnp.float32))
    area = colocation.get("area")
    area = (jnp.zeros(fid.shape[:-2] + (m,), jnp.int32) if area is None
            else jnp.asarray(np.asarray(area), jnp.int32))
    return fid, exch, pos, area


def _build_replay(batches: Any, train_fn: TrainFn, cfg: PopulationConfig, *,
                  method: str, eval_every: Optional[int],
                  eval_fn: Optional[Callable], n_steps: int,
                  has_context: bool) -> Callable:
    """Un-jitted replay core ``(state, fid, exch, pos, area, stacked_batches,
    context, key) -> (state, last_fid, evals)`` closed over the statics."""
    dynamic = callable(batches)
    batch_fn = batches if dynamic else None

    def replay(state, fid, exch, pos, area, stacked_batches, context, key):
        _STATS["traces"] += 1          # python side effect: fires per trace
        step_fn = make_method_step(method, train_fn, cfg, area)
        n_mules = fid.shape[1]
        ts = jnp.arange(n_steps, dtype=jnp.int32)

        def body(carry, xs):
            st, last = carry
            if dynamic:
                fid_t, exch_t, pos_t, t = xs
                kb, ks = jax.random.split(jax.random.fold_in(key, t))
                bt = (batch_fn(kb, t, context) if has_context
                      else batch_fn(kb, t))
            else:
                fid_t, exch_t, pos_t, t, bt = xs
                ks = jax.random.fold_in(key, t)
            st = step_fn(st, {"fixed_id": fid_t, "exchange": exch_t,
                              "pos": pos_t, "t": t}, bt, ks)
            last = jnp.where(fid_t >= 0, fid_t, last)
            return (st, last), None

        def xs_slice(lo, hi):
            xs = (fid[lo:hi], exch[lo:hi], pos[lo:hi], ts[lo:hi])
            if not dynamic:
                xs = xs + (jax.tree.map(lambda l: l[lo:hi], stacked_batches),)
            return xs

        carry = (state, jnp.zeros((n_mules,), jnp.int32))

        if eval_fn is None or not eval_every:
            carry, _ = jax.lax.scan(body, carry, xs_slice(0, n_steps))
            return carry[0], carry[1], None

        ev = ((lambda st, last: eval_fn(st, last, context)) if has_context
              else eval_fn)
        n_ev = n_steps // eval_every

        def chunk(carry, xs):
            carry, _ = jax.lax.scan(body, carry, xs)
            st, last = carry
            return carry, ev(st, last)

        head = jax.tree.map(
            lambda l: l[: n_ev * eval_every].reshape(
                (n_ev, eval_every) + l.shape[1:]), xs_slice(0, n_steps))
        carry, evals = jax.lax.scan(chunk, carry, head)
        if n_ev * eval_every < n_steps:              # trailing partial chunk
            carry, _ = jax.lax.scan(body, carry,
                                    xs_slice(n_ev * eval_every, n_steps))
        return carry[0], carry[1], evals

    return replay


def get_compiled_replay(state, fid, exch, pos, area, batches, context, key,
                        train_fn: TrainFn, cfg: PopulationConfig, *,
                        method: str, eval_every: Optional[int],
                        eval_fn: Optional[Callable],
                        vmapped: bool = False) -> Callable:
    """Fetch (or build + memoize) the jitted replay for this signature.

    ``vmapped=True`` wraps the core in ``jax.vmap`` over a leading stack
    axis on every array argument (``repro.scenarios.sweep`` uses this); the
    leading-axis difference in the shape signature keeps batched and
    unbatched programs in separate cache slots.
    """
    dynamic = callable(batches)
    n_steps = int(fid.shape[-2])
    cache_key = (
        "sweep" if vmapped else "population", method, cfg, eval_every,
        n_steps, train_fn, eval_fn, batches if dynamic else None,
        _sig(state), _sig((fid, exch, pos, area)),
        None if dynamic else _sig(batches),
        None if context is None else _sig(context), _sig(key),
    )
    fn = _JIT_CACHE.get(cache_key)
    if fn is not None:
        _STATS["hits"] += 1
        _JIT_CACHE.move_to_end(cache_key)
        return fn
    _STATS["misses"] += 1
    core = _build_replay(batches, train_fn, cfg, method=method,
                         eval_every=eval_every, eval_fn=eval_fn,
                         n_steps=n_steps, has_context=context is not None)
    if vmapped:
        core = jax.vmap(core)
    fn = jax.jit(core)
    _JIT_CACHE[cache_key] = fn
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return fn


def run_population(state: Dict[str, Any], colocation: Dict[str, Any],
                   batches: Any, train_fn: TrainFn, cfg: PopulationConfig,
                   key, *, eval_every: Optional[int] = None,
                   eval_fn: Optional[Callable] = None,
                   method: str = "mlmule", context: Any = None
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Scan one method over a precomputed co-location schedule (jit-cached).

    state:      population state from ``init_population``.
    colocation: {"fixed_id": [T, M] int32 (-1 = corridor),
                 "exchange": [T, M] bool}; the peer-encounter methods also
                 read "pos" [T, M, 2] and "area" [M] (zero-filled when
                 absent; extra keys ignored).
    batches:    callable ``(key, t[, context]) -> {"fixed": ..., "mule":
                ...}`` sampled inside the scan (traceable), or a pytree of
                stacked ``[T, ...]`` leaves consumed as scan inputs.
    method:     any of ``METHODS_MOBILE`` (see ``make_method_step``).
    context:    optional pytree passed through to ``batches`` and
                ``eval_fn`` as a trailing argument — the hook for per-call
                (or, under ``run_sweep``, per-seed) datasets.
    eval_fn:    optional traceable ``(state, last_fid [M][, context]) ->
                metric pytree`` run inside the scan every ``eval_every``
                steps (``last_fid`` is each mule's most recent fixed
                device, 0 before any visit).

    Returns ``(final_state, aux)`` with
    ``aux = {"last_fid": [M], "eval_steps": np [E], "evals": stacked/None}``
    where eval step ``i`` is taken after step ``(i+1)*eval_every - 1``.
    """
    fid, exch, pos, area = _colocation_tensors(colocation)
    n_steps = fid.shape[0]
    stacked = None if callable(batches) else batches
    fn = get_compiled_replay(state, fid, exch, pos, area, batches, context,
                             key, train_fn, cfg, method=method,
                             eval_every=eval_every, eval_fn=eval_fn)
    state, last, evals = fn(state, fid, exch, pos, area, stacked, context,
                            key)
    n_ev = n_steps // eval_every if (eval_fn is not None and eval_every) else 0
    steps = (np.arange(n_ev) + 1) * eval_every - 1 if n_ev else \
        np.zeros((0,), int)
    return state, {"last_fid": last, "eval_steps": steps, "evals": evals}


def run_population_loop(state: Dict[str, Any], colocation: Dict[str, Any],
                        batches: Any, train_fn: TrainFn,
                        cfg: PopulationConfig, key, *,
                        method: str = "mlmule"
                        ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """The retired per-step harness driver, kept as the parity reference.

    One jitted dispatch per simulation step with Python-level method
    branching — exactly the loop ``benchmarks/common.py`` ran before every
    method moved onto the scan. Parity tests pin ``run_population`` to this
    bitwise at fixed seed; ``benchmarks/engine_micro.py`` times the gap.

    Returns ``(final_state, last_fid)``.
    """
    from repro.baselines import gossip_step, local_step, oppcl_step

    step = jax.jit(lambda s, i, b, k: population_step(s, i, b, train_fn,
                                                      cfg, k))
    jit_local = jax.jit(lambda m, b, k: local_step(m, b, train_fn, k))
    jit_gossip = jax.jit(
        lambda m, p, a, b, k: gossip_step(m, p, a, b, train_fn, k))
    jit_oppcl = jax.jit(
        lambda m, p, a, b, k: oppcl_step(m, p, a, b, train_fn, k))

    fid_T, exch_T, pos_T, area = _colocation_tensors(colocation)
    n_steps, n_mules = fid_T.shape
    dynamic = callable(batches)
    state = dict(state)
    last_fid = jnp.zeros((n_mules,), jnp.int32)
    for t in range(n_steps):
        fid, exch, pos = fid_T[t], exch_T[t], pos_T[t]
        if dynamic:
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            bt = batches(kb, t)
        else:
            ks = jax.random.fold_in(key, t)
            bt = jax.tree.map(lambda l: l[t], batches)
        last_fid = jnp.where(fid >= 0, fid, last_fid)
        if method == "mlmule":
            state = step(state, {"fixed_id": fid, "exchange": exch}, bt, ks)
        elif method == "local":
            side = "fixed_models" if cfg.mode == "fixed" else "mule_models"
            state[side] = jit_local(
                state[side], bt["fixed" if cfg.mode == "fixed" else "mule"],
                ks)
        elif method == "gossip":
            # peer exchange also costs 3 time steps (paper Sec 4.3.1)
            if t % 3 == 2:
                state["mule_models"] = jit_gossip(
                    state["mule_models"], pos, area, bt["mule"], ks)
        elif method == "oppcl":
            if t % 3 == 2:
                state["mule_models"] = jit_oppcl(
                    state["mule_models"], pos, area, bt["mule"], ks)
        elif method == "mlmule+gossip":
            state = step(state, {"fixed_id": fid, "exchange": exch}, bt, ks)
            if t % 3 == 2:
                kg = jax.random.fold_in(ks, 1)
                state["mule_models"] = jit_gossip(
                    state["mule_models"], pos, area, bt["mule"], kg)
        else:
            raise ValueError(method)
    return state, last_fid
