"""Compiled scenario engine: method-dispatched scans with a jit cache.

The harness used to drive the simulation with a per-step Python loop — one
jitted dispatch per time step, thousands of dispatches per experiment. Here
the whole run is one (optionally chunked) ``lax.scan`` over precomputed
``[T, M]`` co-location tensors, with periodic evaluation *inside* the scan,
so a full scenario replay is a single XLA program. Every mobile-protocol
method (``repro.core.population.METHODS_MOBILE``) rides the same engine:
``method=`` selects the per-step update built by ``make_method_step`` (the
baselines' 3-step exchange cadence is a ``lax.cond`` on the step index).

Distributed replay
------------------
``run_population_distributed`` lifts the same scan — ``psum`` collective
schedule included — under ``shard_map`` over the mesh mule (``data``) axis:
mule state and colocation columns shard, fixed-device state replicates, and
``repro.core.distributed.make_distributed_method_step`` supplies the
step, so a mule-sharded experiment is ONE program instead of one
``shard_map`` dispatch per step (``run_population_distributed_loop``
preserves the per-step dispatch pattern as the parity/bench reference).
Every ``METHODS_MOBILE`` method lowers to the distributed
step through the one ``repro.core.method_program`` table — the
peer-encounter baselines cross shards via its ring ``ppermute``
exchange. Multi-seed sweeps compose: ``run_sweep_distributed`` stacks the
seed ``vmap`` axis *inside* the shard_map block (i.e. outside the mule
axis, unsharded), one program per method, bitwise-equal per lane to
sequential distributed runs.

Streaming replay
----------------
``run_population`` scans a *materialized* ``[T, M]`` schedule — at
M=10^5-10^6 the schedule dwarfs the population state.
``run_population_streamed`` replaces the precomputed xs with a chunk
generator (``repro.mobility.streaming``): each compiled dispatch expands
``chunk_len`` steps of colocation *inside the trace* from O(M)-ish compact
arrays and scans them, so schedule memory is O(chunk · M) regardless of
horizon. Its jit cache key hashes the generator signature + chunk shape,
never ``T`` — one compiled program serves any horizon — and state/last
buffers are donated per chunk (``donate_argnums=(0, 1)``). Under a mesh
the generator's arrays shard over the mule axis and each shard expands
only its own columns: the distributed engine never gathers a global
schedule. Parity: a streamed replay is bitwise-equal to ``run_population``
over ``materialize_generator(generator)``, chunk boundaries included,
because ``_scan_core`` is shared and every step keys off its *global*
index.

Jit cache
---------
``run_population`` used to retrace on every call — fine for one replay per
experiment, wasteful for sweeps. Compiled replays are now memoized in a
module-level cache keyed on everything that determines the traced program:

  (kind, method, cfg, eval_every, n_steps,
   train_fn, eval_fn, batch-callable identity,
   shape/dtype signatures of state, colocation tensors, stacked batches,
   context, and the PRNG key;
   plus donation, and — for the distributed kinds — mesh and the
   DistributedConfig)

``cfg`` hashes by value (frozen dataclass); functions hash by identity, so
reuse the *same* ``train_fn``/``batches``/``eval_fn`` objects across calls
to hit the cache (a fresh lambda per call means a fresh trace). The cache
holds strong references but is LRU-bounded (oldest entries evicted past
``_JIT_CACHE_MAX``), so loops that can never hit — e.g. a fresh closure
per experiment — don't accumulate executables and closure-captured data
for process lifetime; ``jit_cache_clear()`` resets it and
``jit_cache_stats()`` reports ``{"traces", "hits", "misses"}`` — the
traces counter increments only when XLA actually retraces, which is what
``benchmarks/engine_micro.py`` asserts goes to zero on repeat calls.

Key discipline (the parity tests rely on reproducing it exactly):

- step ``t`` uses ``k_t = jax.random.fold_in(key, t)``;
- if ``batches`` is a callable ``(key, t) -> batches-dict`` (or
  ``(key, t, context) -> batches-dict`` when a ``context`` pytree is
  passed), the step splits ``kb, ks = jax.random.split(k_t)`` and calls
  ``batches(kb, t[, context])``; the training key is ``ks``;
- if ``batches`` is a pytree of stacked ``[T, ...]`` leaves, step ``t``
  consumes slice ``t`` and trains with ``k_t`` directly.

``run_population_loop`` preserves the retired per-step driver verbatim as
the parity reference (the same role ``trace_to_colocation_loop`` plays for
the vectorized trace expansion): Python-level method dispatch, one jitted
call per step. Tests pin scan-vs-loop bitwise equality per method.

Population churn
----------------
Every path accepts an optional ``"active"`` ``[T, M]`` bool mask in the
colocation dict (``repro.mobility``'s churn mask generators build them):
inactive mules neither train nor exchange nor contribute to space
aggregation for that step, on every method and on the distributed engine
alike (the mask ANDs into the delivery mask before the fused psum, so
distributed == single-host under churn). The mask is *data*, not a static:
dense (absent mask == all-ones) and churned runs of the same shape share
one cache entry and one compiled program — zero retraces.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from collections import OrderedDict

from repro.core.population import (PopulationConfig, TrainFn,
                                   make_method_step, population_step)

# LRU-bounded: callers that build fresh batch/eval closures per experiment
# (their identity is part of the key) can never hit, so eviction caps the
# executables + closure-captured datasets such loops would otherwise leak.
_JIT_CACHE: "OrderedDict[Any, Callable]" = OrderedDict()
_JIT_CACHE_MAX = 32
_STATS = {"traces": 0, "hits": 0, "misses": 0}


def jit_cache_stats(per_process: bool = False) -> Dict[str, int]:
    """Snapshot of engine cache counters (traces/hits/misses).

    ``per_process=True`` prefixes every key with ``p{process_index}/`` so
    retrace assertions aggregated across a ``jax.distributed`` cluster
    (each process has its own cache and counters) stay attributable —
    the scale bench merges the dicts from every rank and pins each
    ``p*/retraces``-style delta to zero by name.
    """
    if not per_process:
        return dict(_STATS)
    prefix = f"p{jax.process_index()}/"
    return {prefix + k: v for k, v in _STATS.items()}


def jit_cache_clear() -> None:
    """Drop all memoized replays and reset the counters."""
    _JIT_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


# jitted gathers for the between-chunk re-bucket swap: an eager gather on
# an array whose shards span processes is rejected outside jit, and under
# jit the same gather is bitwise-identical on a single process
_take_rows = jax.jit(lambda l, o: jnp.take(l, jnp.asarray(o), axis=0))
_take_cols = jax.jit(lambda l, o: jnp.take(l, jnp.asarray(o), axis=1))


def _sig(tree: Any) -> Any:
    """Hashable shape/dtype signature of a pytree (structure included)."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef,) + tuple(
        (tuple(np.shape(l)), np.dtype(jnp.result_type(l)).str) for l in leaves)


def _dev(x, dtype) -> jnp.ndarray:
    """To-device cast that never host-round-trips an existing device array.

    ``jnp.asarray(np.asarray(x))`` copies device arrays back to the host
    and up again — double-buffering ``[T, M]`` schedules for nothing. A
    ``jax.Array`` of the right dtype passes through untouched; the wrong
    dtype casts on device; everything else (numpy, lists) uploads once.
    """
    dtype = np.dtype(dtype)
    if isinstance(x, jax.Array):
        return x if x.dtype == dtype else x.astype(dtype)
    return jnp.asarray(np.asarray(x), dtype)


def _colocation_tensors(colocation, n_steps=None):
    """Normalize a colocation dict to (fid, exch, pos, area, act) arrays.

    ``act`` is the per-step activity (churn) mask ``[T, M]`` bool from the
    ``"active"`` key; absent, it defaults to all-ones — the dense
    population. Because the mask is data (same shape/dtype either way), a
    dense and a churned run of the same schedule shape share one compiled
    replay. Inputs already on device stay on device (no host copy).
    """
    fid = _dev(colocation["fixed_id"], jnp.int32)
    exch = _dev(colocation["exchange"], bool)
    t, m = fid.shape[-2], fid.shape[-1]
    pos = colocation.get("pos")
    pos = (jnp.zeros(fid.shape + (2,), jnp.float32) if pos is None
           else _dev(pos, jnp.float32))
    area = colocation.get("area")
    area = (jnp.zeros(fid.shape[:-2] + (m,), jnp.int32) if area is None
            else _dev(area, jnp.int32))
    act = colocation.get("active")
    act = (jnp.ones(fid.shape, bool) if act is None
           else _dev(act, bool))
    return fid, exch, pos, area, act


def _scan_core(state, last, fid, exch, pos, area, act, ts, stacked_batches,
               context, key, *, dynamic: bool, batch_fn, has_context: bool,
               step_fn, eval_every: Optional[int],
               eval_fn: Optional[Callable]):
    """Traceable scan over one contiguous window of the schedule.

    ``ts`` carries the *global* step indices of the window (the streamed
    path hands in ``t0 + arange(chunk)``), so the per-step
    ``fold_in(key, t)`` discipline — and with it bitwise parity against a
    full-horizon replay — is independent of how the horizon is chunked.
    ``last`` enters as carry for the same reason.

    ``area`` is the static [M] vector of the classic contract, or a
    time-varying [T, M] trace (migratory scenarios) — the latter rides the
    scan as one more xs column, so step ``t`` hands the method step its
    *current* row through ``info["area"]``. Returns
    ``(state, last_fid, evals-or-None)``.
    """
    n_steps = fid.shape[0]
    area_dyn = area.ndim == fid.ndim

    def body(carry, xs):
        st, last = carry
        if area_dyn:
            fid_t, exch_t, pos_t, act_t, area_t = xs[:5]
            rest = xs[5:]
        else:
            fid_t, exch_t, pos_t, act_t = xs[:4]
            area_t = area
            rest = xs[4:]
        if dynamic:
            (t,) = rest
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            bt = (batch_fn(kb, t, context) if has_context
                  else batch_fn(kb, t))
        else:
            t, bt = rest
            ks = jax.random.fold_in(key, t)
        st = step_fn(st, {"fixed_id": fid_t, "exchange": exch_t,
                          "pos": pos_t, "area": area_t, "active": act_t,
                          "t": t}, bt, ks)
        last = jnp.where((fid_t >= 0) & act_t, fid_t, last)
        return (st, last), None

    def xs_slice(lo, hi):
        xs = (fid[lo:hi], exch[lo:hi], pos[lo:hi], act[lo:hi])
        if area_dyn:
            xs = xs + (area[lo:hi],)
        xs = xs + (ts[lo:hi],)
        if not dynamic:
            xs = xs + (jax.tree.map(lambda l: l[lo:hi], stacked_batches),)
        return xs

    carry = (state, last)

    if eval_fn is None or not eval_every:
        carry, _ = jax.lax.scan(body, carry, xs_slice(0, n_steps))
        return carry[0], carry[1], None

    ev = ((lambda st, last: eval_fn(st, last, context)) if has_context
          else eval_fn)
    n_ev = n_steps // eval_every

    def chunk(carry, xs):
        carry, _ = jax.lax.scan(body, carry, xs)
        st, last = carry
        return carry, ev(st, last)

    head = jax.tree.map(
        lambda l: l[: n_ev * eval_every].reshape(
            (n_ev, eval_every) + l.shape[1:]), xs_slice(0, n_steps))
    carry, evals = jax.lax.scan(chunk, carry, head)
    if n_ev * eval_every < n_steps:              # trailing partial chunk
        carry, _ = jax.lax.scan(body, carry,
                                xs_slice(n_ev * eval_every, n_steps))
    return carry[0], carry[1], evals


def _build_replay(batches: Any, train_fn: TrainFn, cfg: PopulationConfig, *,
                  method: str, eval_every: Optional[int],
                  eval_fn: Optional[Callable], n_steps: int,
                  has_context: bool,
                  step_builder: Optional[Callable] = None) -> Callable:
    """Un-jitted replay core ``(state, fid, exch, pos, area, stacked_batches,
    context, key) -> (state, last_fid, evals)`` closed over the statics.

    ``step_builder(area) -> step_fn`` overrides the per-step update (the
    distributed engine injects its shard-local collective step here); the
    default is the single-host ``make_method_step`` dispatch.

    The activity mask rides the scan as one more ``[T, M]`` xs column:
    step ``t`` hands ``act[t]`` to the method step as ``info["active"]``
    and gates ``last_fid`` (a sleeping mule records no visit).
    """
    dynamic = callable(batches)
    batch_fn = batches if dynamic else None
    if step_builder is None:
        step_builder = lambda area: make_method_step(method, train_fn, cfg,
                                                     area)

    def replay(state, fid, exch, pos, area, act, stacked_batches, context,
               key):
        _STATS["traces"] += 1          # python side effect: fires per trace
        step_fn = step_builder(area)
        n_mules = fid.shape[1]
        ts = jnp.arange(n_steps, dtype=jnp.int32)
        last = jnp.zeros((n_mules,), jnp.int32)
        return _scan_core(state, last, fid, exch, pos, area, act, ts,
                          stacked_batches, context, key, dynamic=dynamic,
                          batch_fn=batch_fn, has_context=has_context,
                          step_fn=step_fn, eval_every=eval_every,
                          eval_fn=eval_fn)

    return replay


def _build_chunk_replay(generator, batches: Any, train_fn: TrainFn,
                        cfg: PopulationConfig, *, method: str,
                        eval_every: Optional[int],
                        eval_fn: Optional[Callable], chunk_len: int,
                        has_context: bool,
                        step_builder: Optional[Callable] = None,
                        rebucket: bool = False,
                        pmean_axis: Optional[str] = None) -> Callable:
    """Un-jitted streamed-chunk core ``(state, last, t0, gen_arrays,
    stacked_chunk, context, key) -> (state, last_fid, evals)``.

    The colocation slice is *generated inside the trace*: the generator's
    ``expand`` runs on its array pytree (a traced input — under
    ``shard_map`` each shard holds and expands only its own mule columns)
    at global steps ``t0 .. t0+chunk_len``, feeding the same ``_scan_core``
    the materialized path scans. Only the generator's *static* config is
    closed over, so one compiled program serves every same-shape chunk of
    every same-signature generator, whatever the horizon.

    ``rebucket=True`` compiles the re-bucketing variant: the signature
    grows a ``bucket_area`` input after ``gen_arrays`` (each mule's area at
    the last bucket swap, shard-local under shard_map) and the return grows
    ``(drift, area_last)`` before ``evals`` — the fraction of mules whose
    end-of-chunk area left their bucket (``pmean``'d over ``pmean_axis``
    into a replicated scalar, so the trigger costs one tiny collective per
    chunk) and the end-of-chunk area vector the host driver argsorts into
    the next bucket order when the drift crosses the threshold.
    """
    dynamic = callable(batches)
    batch_fn = batches if dynamic else None
    if step_builder is None:
        step_builder = lambda area: make_method_step(method, train_fn, cfg,
                                                     area)

    def chunk_replay(state, last, t0, gen_arrays, *rest):
        if rebucket:
            bucket_area, stacked_chunk, context, key = rest
        else:
            stacked_chunk, context, key = rest
        _STATS["traces"] += 1          # python side effect: fires per trace
        ts = jnp.asarray(t0, jnp.int32) + jnp.arange(chunk_len,
                                                     dtype=jnp.int32)
        co = generator.expand(gen_arrays, None, t0, chunk_len)
        step_fn = step_builder(co["area"])
        out = _scan_core(state, last, co["fixed_id"], co["exchange"],
                         co["pos"], co["area"], co["active"], ts,
                         stacked_chunk, context, key, dynamic=dynamic,
                         batch_fn=batch_fn, has_context=has_context,
                         step_fn=step_fn, eval_every=eval_every,
                         eval_fn=eval_fn)
        if not rebucket:
            return out
        st, last_fid, evals = out
        area_arr = co["area"]
        area_end = area_arr[-1] if area_arr.ndim == 2 else area_arr
        drift = jnp.mean((area_end != bucket_area).astype(jnp.float32))
        if pmean_axis:
            # ordered, not lax.pmean: the swap decision must be identical
            # on every process/backend or ranks could disagree on whether
            # to reorder (and single- vs multi-process runs would diverge)
            from repro.core.distributed import ordered_pmean
            drift = ordered_pmean(drift, pmean_axis)
        return st, last_fid, drift, jnp.asarray(area_end, jnp.int32), evals

    return chunk_replay


def _distributed_specs(state, batches, dcfg, *, vmapped: bool,
                       area_dyn: bool = False):
    """shard_map in/out PartitionSpecs for the distributed replay.

    Mule-population leaves (leading mule axis) shard over ``dcfg.data_axis``;
    everything else replicates. With ``vmapped`` the seed stack axis is an
    extra unsharded leading dim (the seed vmap sits *inside* the shard_map
    block, outside the mule axis). ``area_dyn`` marks a time-varying
    [T, M] area trace, which shards like the other colocation columns.
    """
    from jax.sharding import PartitionSpec as P
    ax = dcfg.data_axis
    lead = (None,) if vmapped else ()

    def subtree(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    state_specs = {
        k: subtree(v, P(*lead, ax) if k.startswith("mule") else P())
        for k, v in state.items()
    }
    if callable(batches) or batches is None:
        batch_specs = P()                       # no leaves to partition
    else:
        batch_specs = {
            k: subtree(v, P(*lead, None, ax) if k == "mule" else P())
            for k, v in batches.items()
        }
    area_spec = P(*lead, None, ax) if area_dyn else P(*lead, ax)
    in_specs = (state_specs,
                P(*lead, None, ax), P(*lead, None, ax),   # fid, exch
                P(*lead, None, ax), area_spec,            # pos, area
                P(*lead, None, ax),                       # activity mask
                batch_specs, P(), P())                    # batches, ctx, key
    out_specs = (state_specs, P(*lead, ax), P())          # state, last, evals
    return in_specs, out_specs


def get_compiled_replay(state, fid, exch, pos, area, act, batches, context,
                        key, train_fn: TrainFn, cfg: PopulationConfig, *,
                        method: str, eval_every: Optional[int],
                        eval_fn: Optional[Callable],
                        vmapped: bool = False, donate: bool = False,
                        mesh=None, dcfg=None) -> Callable:
    """Fetch (or build + memoize) the jitted replay for this signature.

    ``vmapped=True`` wraps the core in ``jax.vmap`` over a leading stack
    axis on every array argument (``repro.scenarios.sweep`` uses this); the
    leading-axis difference in the shape signature keeps batched and
    unbatched programs in separate cache slots.

    ``mesh``/``dcfg`` select the distributed kind: the (possibly vmapped)
    core is wrapped in ``shard_map`` over the mesh with the step from
    ``make_distributed_method_step``, and both join the cache key.

    ``donate=True`` donates the state pytree (``donate_argnums=(0,)``) so
    the replay reuses its buffers in place — callers must not touch the
    input state afterwards; parity paths that replay the same state twice
    keep the default. Donated and undonated programs cache separately.
    """
    dynamic = callable(batches)
    n_steps = int(fid.shape[-2])
    kind = (("distributed_sweep" if vmapped else "distributed")
            if mesh is not None else ("sweep" if vmapped else "population"))
    cache_key = (
        kind, method, cfg, eval_every,
        n_steps, train_fn, eval_fn, batches if dynamic else None,
        _sig(state), _sig((fid, exch, pos, area, act)),
        None if dynamic else _sig(batches),
        None if context is None else _sig(context), _sig(key),
        donate, None if mesh is None else (mesh, dcfg),
    )
    fn = _JIT_CACHE.get(cache_key)
    if fn is not None:
        _STATS["hits"] += 1
        _JIT_CACHE.move_to_end(cache_key)
        return fn
    _STATS["misses"] += 1
    step_builder = None
    if mesh is not None:
        from repro.core.distributed import make_distributed_method_step
        dist_step = make_distributed_method_step(method, train_fn, dcfg,
                                                 mesh=mesh)
        step_builder = lambda area: dist_step
    core = _build_replay(batches, train_fn, cfg, method=method,
                         eval_every=eval_every, eval_fn=eval_fn,
                         n_steps=n_steps, has_context=context is not None,
                         step_builder=step_builder)
    if vmapped:
        core = jax.vmap(core)
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        in_specs, out_specs = _distributed_specs(
            state, batches, dcfg, vmapped=vmapped,
            area_dyn=np.ndim(area) == np.ndim(fid))
        core = shard_map(core, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    fn = jax.jit(core, donate_argnums=(0,) if donate else ())
    _JIT_CACHE[cache_key] = fn
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return fn


def _streamed_specs(state, generator, batches, dcfg, *,
                    rebucket: bool = False):
    """shard_map in/out PartitionSpecs for the streamed chunk replay.

    Argument order mirrors ``_build_chunk_replay``: (state, last, t0,
    gen_arrays[, bucket_area], stacked_chunk, context, key). Mule-population
    leaves and the generator's mule-leading arrays (its ``specs`` method
    knows which) shard over ``dcfg.data_axis``; ``t0``/context/key
    replicate. The re-bucketing variant adds the sharded ``bucket_area``
    input and the ``(drift replicated, area_last sharded)`` outputs.
    """
    from jax.sharding import PartitionSpec as P
    ax = dcfg.data_axis

    def subtree(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    state_specs = {
        k: subtree(v, P(ax) if k.startswith("mule") else P())
        for k, v in state.items()
    }
    if callable(batches) or batches is None:
        batch_specs = P()
    else:
        batch_specs = {
            k: subtree(v, P(None, ax) if k == "mule" else P())
            for k, v in batches.items()
        }
    if rebucket:
        in_specs = (state_specs, P(ax), P(), generator.specs(ax), P(ax),
                    batch_specs, P(), P())
        out_specs = (state_specs, P(ax), P(), P(ax), P())
    else:
        in_specs = (state_specs, P(ax), P(), generator.specs(ax),
                    batch_specs, P(), P())
        out_specs = (state_specs, P(ax), P())
    return in_specs, out_specs


def get_compiled_chunk_replay(state, generator, gen_arrays, batches, context,
                              key, train_fn: TrainFn, cfg: PopulationConfig,
                              *, method: str, eval_every: Optional[int],
                              eval_fn: Optional[Callable], chunk_len: int,
                              stacked_chunk: Any = None, donate: bool = True,
                              mesh=None, dcfg=None,
                              rebucket: bool = False) -> Callable:
    """Fetch (or build + memoize) the jitted streamed-chunk replay.

    The cache key is deliberately **horizon-free**: it hashes the
    generator's *class + static_token() + array signature* and the chunk
    shape, never ``n_steps`` or ``t0`` — so replaying 10^3 or 10^7 steps
    through the same generator family compiles exactly one program per
    distinct chunk length (the tail chunk, when ``n_steps % chunk_len``,
    is the one extra entry). ``donate=True`` (the default here — streaming
    exists for populations too big to copy) donates *state and last_fid*
    (``donate_argnums=(0, 1)``), so the carry ping-pongs through the same
    buffers across the whole chunk loop.
    """
    dynamic = callable(batches)
    kind = ("stream_distributed" if mesh is not None else "stream") \
        + ("_rebucket" if rebucket else "")
    cache_key = (
        kind, method, cfg, eval_every, chunk_len,
        type(generator).__qualname__, generator.static_token(),
        train_fn, eval_fn, batches if dynamic else None,
        _sig(state), _sig(gen_arrays),
        None if dynamic else _sig(stacked_chunk),
        None if context is None else _sig(context), _sig(key),
        donate, None if mesh is None else (mesh, dcfg),
    )
    fn = _JIT_CACHE.get(cache_key)
    if fn is not None:
        _STATS["hits"] += 1
        _JIT_CACHE.move_to_end(cache_key)
        return fn
    _STATS["misses"] += 1
    step_builder = None
    if mesh is not None:
        from repro.core.distributed import make_distributed_method_step
        dist_step = make_distributed_method_step(method, train_fn, dcfg,
                                                 mesh=mesh)
        step_builder = lambda area: dist_step
    core = _build_chunk_replay(generator, batches, train_fn, cfg,
                               method=method, eval_every=eval_every,
                               eval_fn=eval_fn, chunk_len=chunk_len,
                               has_context=context is not None,
                               step_builder=step_builder, rebucket=rebucket,
                               pmean_axis=(dcfg.data_axis
                                           if mesh is not None else None))
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        in_specs, out_specs = _streamed_specs(state, generator, batches,
                                              dcfg, rebucket=rebucket)
        core = shard_map(core, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    fn = jax.jit(core, donate_argnums=(0, 1) if donate else ())
    _JIT_CACHE[cache_key] = fn
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return fn


def run_population_streamed(state: Dict[str, Any], generator, batches: Any,
                            train_fn: TrainFn, cfg: PopulationConfig, key, *,
                            n_steps: Optional[int] = None,
                            chunk_len: int = 64,
                            eval_every: Optional[int] = None,
                            eval_fn: Optional[Callable] = None,
                            method: str = "mlmule", context: Any = None,
                            donate: bool = True, mesh=None, dcfg=None
                            ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """``run_population`` without the ``[T, M]`` schedule: colocation is
    generated chunk-by-chunk *inside* the compiled replay.

    generator: a chunk generator (``repro.mobility.streaming``) —
               ``compact_colocation(...)`` streams any registered
               scenario's schedule from per-mule RLE segments;
               ``commuter_stream(...)`` is fully procedural (O(M) memory,
               any horizon). Schedule memory is O(chunk_len · M) live
               slices plus the generator's compact arrays, never O(T · M).
    n_steps:   horizon; defaults to ``generator.n_steps``.
    chunk_len: steps generated + scanned per compiled dispatch. Must be a
               multiple of ``eval_every`` when ``eval_fn`` is set (so
               evals land on the same global steps as the materialized
               engine). Bigger chunks amortize dispatch; smaller chunks
               shrink the live schedule slice.
    donate:    default **True** (unlike ``run_population``): state and
               ``last_fid`` buffers are donated each chunk and rebound,
               so the population updates in place for the whole run. Pass
               ``False`` when replaying the same input state again.
    mesh/dcfg: run distributed — the generator expands *shard-locally*
               under ``shard_map`` (each shard computes only its own mule
               columns; no global schedule is ever gathered). ``dcfg`` is
               required with a mesh; ``mesh=None`` with a ``dcfg`` picks
               one like ``run_population_distributed``. ``cfg`` is
               ignored in favor of ``dcfg.pop`` when ``dcfg`` is set.

    Mid-run re-bucketing (``dcfg.rebucket_every > 0``): every
    ``rebucket_every`` steps — which must be a multiple of ``chunk_len``,
    so the check lands on a chunk boundary where ``generator.expand`` gives
    a natural sync point — the compiled chunk emits the psum'd fraction of
    mules whose area drifted off their bucket. Past
    ``dcfg.rebucket_threshold``, the driver argsorts the end-of-chunk area
    into a fresh bucket order and permutes the full live mule state
    (``reorder_mule_state`` — models, timestamps, every ``mule*`` carry),
    the ``last_fid`` column, the generator's in-flight mule columns
    (``reorder_generator_arrays``) and any stacked mule batches, so the
    ring's hop pruning keeps biting as the population migrates.
    ``aux["rebucket"]`` reports ``{checks, swaps, drift, order}`` (``order``
    is the cumulative permutation: entry ``p`` is the original index of the
    mule now in slot ``p`` — apply it to per-mule outputs to recover the
    input ordering). Note a swap renumbers mule slots, so positional batch
    callables and per-mule key draws follow the *slot*, exactly like
    build-time bucketing — a re-bucketed run is the same simulation family
    with mules renamed mid-run, and parity (pruned == full ring, streamed
    == materialized) holds across every swap because the trigger depends
    only on the area schedule, never on pruning or model state.

    Everything else (batches/eval/method/context contracts, the returned
    ``(final_state, aux)``) matches ``run_population`` — and so do the
    results: a streamed replay is bitwise-equal to the materialized engine
    over ``materialize_generator(generator)``, chunk boundaries included
    (the global-step key discipline makes chunking invisible).
    """
    if mesh is not None and dcfg is None:
        raise ValueError("run_population_streamed: mesh requires dcfg")
    if key is None:
        raise TypeError("run_population_streamed() missing required "
                        "argument: 'key'")
    pcfg = dcfg.pop if dcfg is not None else cfg
    n_steps = int(generator.n_steps if n_steps is None else n_steps)
    n_mules = int(generator.n_mules)
    if chunk_len <= 0:
        raise ValueError(f"chunk_len={chunk_len} must be positive")
    if eval_fn is not None and eval_every and chunk_len % eval_every:
        raise ValueError(
            f"chunk_len={chunk_len} must be a multiple of "
            f"eval_every={eval_every} so streamed evals land on the same "
            f"global steps as the materialized engine")
    rb = int(getattr(dcfg, "rebucket_every", 0) or 0) if dcfg is not None \
        else 0
    if rb > 0 and rb % chunk_len:
        raise ValueError(
            f"rebucket_every={rb} must be a multiple of "
            f"chunk_len={chunk_len} so re-bucketing lands on chunk "
            "boundaries (the streamed engine swaps state between chunks)")
    if dcfg is not None:
        dcfg = _resolve_ring_bits(dcfg, getattr(generator, "max_area", 0))
        if mesh is None:
            mesh = _auto_mesh(method, n_mules, dcfg)
        _check_mule_sharding(n_mules, mesh, dcfg)
    gen_arrays = generator.arrays()
    dynamic = callable(batches)
    last = jnp.zeros((n_mules,), jnp.int32)
    evals_chunks = []
    rebucket = rb > 0
    rb_aux = None
    if rebucket:
        from repro.core.distributed import (global_bucket_order,
                                            reorder_mule_state)
        from repro.mobility.streaming import reorder_generator_arrays
        a0 = generator.expand(gen_arrays, None, jnp.asarray(0, jnp.int32),
                              1)["area"]
        bucket_area = jnp.asarray(a0[0] if a0.ndim == 2 else a0, jnp.int32)
        threshold = float(getattr(dcfg, "rebucket_threshold", 0.25))
        rb_aux = {"checks": 0, "swaps": 0, "drift": [],
                  "order": np.arange(n_mules)}
    # under jax.distributed the mesh spans processes: commit every input
    # through the placement helpers (sharded leaves hand the runtime only
    # this process's row block); single-process runs skip all of this
    multiproc = mesh is not None and jax.process_count() > 1
    if multiproc:
        from jax.sharding import PartitionSpec as P
        from repro.launch.multiprocess import (host_replicated, put_global,
                                               put_global_tree)
        in_specs, _ = _streamed_specs(state, generator, batches, dcfg,
                                      rebucket=rebucket)
        ax = dcfg.data_axis
        state = put_global_tree(state, mesh, in_specs[0])
        last = put_global(last, mesh, P(ax))
        gen_arrays = put_global_tree(gen_arrays, mesh, generator.specs(ax))
        key = put_global(key, mesh, P())
        if context is not None:
            context = put_global_tree(
                context, mesh, jax.tree.map(lambda _: P(), context))
        if rebucket:
            bucket_area = put_global(bucket_area, mesh, P(ax))
        batch_specs = in_specs[5] if rebucket else in_specs[4]
    for t0 in range(0, n_steps, chunk_len):
        cl = min(chunk_len, n_steps - t0)
        stacked_chunk = (None if dynamic else
                         jax.tree.map(lambda l: l[t0:t0 + cl], batches))
        t0_dev = jnp.asarray(t0, jnp.int32)
        if multiproc:
            t0_dev = put_global(t0_dev, mesh, P())
            if stacked_chunk is not None:
                stacked_chunk = put_global_tree(stacked_chunk, mesh,
                                                batch_specs)
        fn = get_compiled_chunk_replay(
            state, generator, gen_arrays, batches, context, key, train_fn,
            pcfg, method=method, eval_every=eval_every, eval_fn=eval_fn,
            chunk_len=cl, stacked_chunk=stacked_chunk, donate=donate,
            mesh=mesh, dcfg=dcfg, rebucket=rebucket)
        if rebucket:
            state, last, drift, area_last, ev = fn(
                state, last, t0_dev, gen_arrays,
                bucket_area, stacked_chunk, context, key)
        else:
            state, last, ev = fn(state, last, t0_dev,
                                 gen_arrays, stacked_chunk, context, key)
        if ev is not None:
            evals_chunks.append(ev)
        t_end = t0 + cl
        if rebucket and t_end % rb == 0 and t_end < n_steps:
            rb_aux["checks"] += 1
            # drift is replicated; multi-process arrays span devices that
            # np.asarray refuses, so read this process's replica
            d = float(drift) if not multiproc else \
                float(host_replicated(drift))
            rb_aux["drift"].append(d)
            if d > threshold:
                # the bucket order comes out of a compiled exact-int psum
                # + replicated stable argsort (multi-host safe: the [M]
                # area vector is sharded across processes, so no single
                # host could np.argsort it) — bitwise the same decision
                # as the former host-side np.argsort(kind="stable")
                order_r, area_r = global_bucket_order(
                    area_last, mesh, dcfg.data_axis)
                if multiproc:
                    order = host_replicated(order_r)
                    area_now = host_replicated(area_r)
                else:
                    order = np.asarray(order_r)
                    area_now = np.asarray(area_r)
                if not np.array_equal(order, np.arange(n_mules)):
                    state = reorder_mule_state(state, order)
                    last = _take_rows(last, order)
                    gen_arrays = reorder_generator_arrays(
                        generator, gen_arrays, order)
                    if not dynamic:
                        batches = {
                            k: (jax.tree.map(
                                lambda l: _take_cols(l, order), v)
                                if k == "mule" else v)
                            for k, v in batches.items()}
                    rb_aux["order"] = rb_aux["order"][order]
                    rb_aux["swaps"] += 1
                # the current area in the (possibly) new layout is the
                # baseline the next drift check measures against
                bucket_area = jnp.asarray(area_now[order], jnp.int32)
                if multiproc:
                    bucket_area = put_global(bucket_area, mesh, P(ax))
    n_ev = n_steps // eval_every if (eval_fn is not None and eval_every) else 0
    steps = (np.arange(n_ev) + 1) * eval_every - 1 if n_ev else \
        np.zeros((0,), int)
    evals = None
    if evals_chunks:
        evals = (evals_chunks[0] if len(evals_chunks) == 1 else
                 jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *evals_chunks))
    aux = {"last_fid": last, "eval_steps": steps, "evals": evals}
    if rb_aux is not None:
        aux["rebucket"] = rb_aux
    return state, aux


def run_population(state: Dict[str, Any], colocation: Dict[str, Any],
                   batches: Any, train_fn: TrainFn, cfg: PopulationConfig,
                   key, *, eval_every: Optional[int] = None,
                   eval_fn: Optional[Callable] = None,
                   method: str = "mlmule", context: Any = None,
                   donate: bool = False
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Scan one method over a precomputed co-location schedule (jit-cached).

    state:      population state from ``init_population``.
    colocation: {"fixed_id": [T, M] int32 (-1 = corridor),
                 "exchange": [T, M] bool}; the peer-encounter methods also
                 read "pos" [T, M, 2] and "area" [M] (zero-filled when
                 absent; extra keys ignored). An optional "active" [T, M]
                 bool churn mask switches mules off per step: inactive
                 mules neither train, nor exchange, nor count toward space
                 aggregation, and record no ``last_fid`` visit (all-ones ==
                 the dense population, bitwise — same compiled program,
                 the mask is data).
    batches:    callable ``(key, t[, context]) -> {"fixed": ..., "mule":
                ...}`` sampled inside the scan (traceable), or a pytree of
                stacked ``[T, ...]`` leaves consumed as scan inputs.
    method:     any of ``METHODS_MOBILE`` (see ``make_method_step``).
    context:    optional pytree passed through to ``batches`` and
                ``eval_fn`` as a trailing argument — the hook for per-call
                (or, under ``run_sweep``, per-seed) datasets.
    eval_fn:    optional traceable ``(state, last_fid [M][, context]) ->
                metric pytree`` run inside the scan every ``eval_every``
                steps (``last_fid`` is each mule's most recent fixed
                device, 0 before any visit).
    donate:     donate the state buffers to the compiled replay (in-place
                update for large populations). The input ``state`` arrays
                are dead after the call — leave False when replaying the
                same state again (parity tests do).

    Returns ``(final_state, aux)`` with
    ``aux = {"last_fid": [M], "eval_steps": np [E], "evals": stacked/None}``
    where eval step ``i`` is taken after step ``(i+1)*eval_every - 1``.
    """
    fid, exch, pos, area, act = _colocation_tensors(colocation)
    n_steps = fid.shape[0]
    stacked = None if callable(batches) else batches
    fn = get_compiled_replay(state, fid, exch, pos, area, act, batches,
                             context, key, train_fn, cfg, method=method,
                             eval_every=eval_every, eval_fn=eval_fn,
                             donate=donate)
    state, last, evals = fn(state, fid, exch, pos, area, act, stacked,
                            context, key)
    n_ev = n_steps // eval_every if (eval_fn is not None and eval_every) else 0
    steps = (np.arange(n_ev) + 1) * eval_every - 1 if n_ev else \
        np.zeros((0,), int)
    return state, {"last_fid": last, "eval_steps": steps, "evals": evals}


def run_population_loop(state: Dict[str, Any], colocation: Dict[str, Any],
                        batches: Any, train_fn: TrainFn,
                        cfg: PopulationConfig, key, *,
                        method: str = "mlmule", context: Any = None
                        ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """The retired per-step harness driver, kept as the parity reference.

    One jitted dispatch per simulation step with Python-level method
    branching — exactly the loop ``benchmarks/common.py`` ran before every
    method moved onto the scan. Parity tests pin ``run_population`` to this
    bitwise at fixed seed; ``benchmarks/engine_micro.py`` times the gap.

    ``context`` mirrors the scan path's hook: when set (and ``batches`` is
    a callable) the loop calls ``batches(kb, t, context)``, so parity tests
    cover context-carrying runs too.

    Churn: a colocation ``"active"`` mask replays with the same per-step
    Python dispatch — inactive mules skip training/exchange and keep their
    models via ``apply_activity_mask``, mirroring the scan's masked method
    steps operation for operation. Without the key the loop is the
    pre-mask driver verbatim.

    Returns ``(final_state, last_fid)``.
    """
    from repro.baselines import gossip_step, local_step, oppcl_step
    from repro.core.population import apply_activity_mask

    step = jax.jit(lambda s, i, b, k: population_step(s, i, b, train_fn,
                                                      cfg, k))
    jit_local = jax.jit(lambda m, b, k: local_step(m, b, train_fn, k))
    jit_gossip = jax.jit(
        lambda m, p, a, b, k, act: gossip_step(m, p, a, b, train_fn, k,
                                               active=act,
                                               backend=cfg.enc_backend))
    jit_oppcl = jax.jit(
        lambda m, p, a, b, k, act: oppcl_step(m, p, a, b, train_fn, k,
                                              active=act))
    mask_sel = jax.jit(apply_activity_mask)

    fid_T, exch_T, pos_T, area_A, act_T = _colocation_tensors(colocation)
    area_dyn = area_A.ndim == 2
    masked = "active" in colocation and colocation["active"] is not None
    n_steps, n_mules = fid_T.shape
    dynamic = callable(batches)
    state = dict(state)
    last_fid = jnp.zeros((n_mules,), jnp.int32)
    for t in range(n_steps):
        fid, exch, pos = fid_T[t], exch_T[t], pos_T[t]
        area = area_A[t] if area_dyn else area_A
        act = act_T[t] if masked else None
        if dynamic:
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            bt = batches(kb, t, context) if context is not None else \
                batches(kb, t)
        else:
            ks = jax.random.fold_in(key, t)
            bt = jax.tree.map(lambda l: l[t], batches)
        present = (fid >= 0) if act is None else ((fid >= 0) & act)
        last_fid = jnp.where(present, fid, last_fid)
        info = {"fixed_id": fid, "exchange": exch}
        if act is not None:
            info["active"] = act
        if method == "mlmule":
            state = step(state, info, bt, ks)
        elif method == "local":
            side = "fixed_models" if cfg.mode == "fixed" else "mule_models"
            trained = jit_local(
                state[side], bt["fixed" if cfg.mode == "fixed" else "mule"],
                ks)
            if side == "mule_models":
                trained = mask_sel(act, trained, state[side])
            state[side] = trained
        elif method == "gossip":
            # peer exchange also costs 3 time steps (paper Sec 4.3.1)
            if t % 3 == 2:
                new = jit_gossip(state["mule_models"], pos, area, bt["mule"],
                                 ks, act)
                state["mule_models"] = mask_sel(act, new,
                                                state["mule_models"])
        elif method == "oppcl":
            if t % 3 == 2:
                new = jit_oppcl(state["mule_models"], pos, area, bt["mule"],
                                ks, act)
                state["mule_models"] = mask_sel(act, new,
                                                state["mule_models"])
        elif method == "mlmule+gossip":
            state = step(state, info, bt, ks)
            if t % 3 == 2:
                kg = jax.random.fold_in(ks, 1)
                new = jit_gossip(state["mule_models"], pos, area, bt["mule"],
                                 kg, act)
                state["mule_models"] = mask_sel(act, new,
                                                state["mule_models"])
        else:
            raise ValueError(method)
    return state, last_fid


# ---------------------------------------------------------------------------
# distributed replay: the scan under shard_map over the mule axis
# ---------------------------------------------------------------------------


def _resolve_ring_bits(dcfg, max_area):
    """Pick the ring predicate width when ``dcfg.ring_bits == 0`` (auto).

    Widens to 64 bits once any area id reaches 32 — a 32-wide mask folds
    areas ``% 32``, aliasing distinct areas onto one bit so the ring
    quietly stops pruning. Safe to resolve per-run: pruning is exact, so
    the mask width never changes results, only the prune rate (and the
    jit cache key, which hashes the resolved config by value).
    """
    import dataclasses
    if getattr(dcfg, "ring_bits", 0):
        return dcfg
    return dataclasses.replace(dcfg,
                               ring_bits=64 if int(max_area) >= 32 else 32)


def _check_mule_sharding(n_mules: int, mesh, dcfg) -> None:
    shards = mesh.shape[dcfg.data_axis]
    if n_mules % shards:
        raise ValueError(
            f"n_mules={n_mules} must divide evenly over the "
            f"{dcfg.data_axis!r} mesh axis (size {shards})")


def _auto_mesh(method: str, n_mules: int, dcfg):
    """Mesh for ``run_population_distributed(mesh=None)``.

    Consults ``suggest_mesh_shape`` — the roofline-ranked (pod, data)
    shape from the committed ``BENCH_roofline.json`` mesh rows — the way
    the kernels consult ``tuned_block_d``; a suggestion that doesn't fit
    this process (too few devices, a data size that doesn't divide
    ``n_mules``, a pod axis the dcfg doesn't carry) falls back, like an
    absent cache, to the largest single-pod data axis the local devices
    allow.
    """
    import jax
    from repro.launch.autotune import suggest_mesh_shape
    from repro.launch.mesh import make_mule_mesh

    n_dev = jax.device_count()
    shape = suggest_mesh_shape(method, n_mules)
    if shape is not None:
        pod, data = shape
        if (pod * data <= n_dev and data and n_mules % data == 0
                and (dcfg.pod_axis or pod == 1)):
            return make_mule_mesh(pod, data, pod_axis=dcfg.pod_axis,
                                  data_axis=dcfg.data_axis)
    data = max(d for d in range(1, n_dev + 1) if n_mules % d == 0)
    return make_mule_mesh(1, data, pod_axis=dcfg.pod_axis,
                          data_axis=dcfg.data_axis)


def run_population_distributed(state: Dict[str, Any],
                               colocation: Dict[str, Any], batches: Any,
                               train_fn: TrainFn, dcfg, mesh=None, key=None, *,
                               eval_every: Optional[int] = None,
                               eval_fn: Optional[Callable] = None,
                               method: str = "mlmule", context: Any = None,
                               donate: bool = False
                               ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """``run_population`` with the population sharded over the mesh.

    The whole replay — the ``psum`` collective schedule of
    ``make_distributed_method_step`` included — is one ``lax.scan`` under
    ``shard_map`` over ``dcfg.data_axis`` (jit-cached like the single-host
    path; the mesh and ``dcfg`` join the cache key). Mule state/colocation
    columns shard, fixed-device state and the freshness sketch replicate.

    state:   ``to_distributed_state(init_population(...), dcfg)`` layout.
    dcfg:    ``repro.core.distributed.DistributedConfig`` — collective
             schedule (``cross_pod``) and axis names; the freshness
             statistic comes from ``dcfg.pop.freshness.stat``.
    mesh:    a ``jax.sharding.Mesh`` whose axes include ``dcfg.data_axis``
             (and ``dcfg.pod_axis`` when set). ``n_mules`` must divide the
             data-axis size. ``None`` picks a shape automatically: the
             roofline-ranked suggestion from the committed
             ``BENCH_roofline.json`` mesh rows (``suggest_mesh_shape``,
             consulted the way the kernels consult ``tuned_block_d``),
             falling back to the widest fitting single-pod data axis.
    batches: the ``run_population`` contract; a batch callable runs inside
             every shard on the replicated key, so it must be
             deterministic in ``(key, t[, context])``; full ``[n_mules,
             ...]`` mule batches are sliced per shard by the step. Stacked
             pytrees shard their ``"mule"`` leaves.
    eval_fn: runs shard-local with replicated outputs assumed — read
             replicated state (``fixed_models``) / replicated context only.
    method:  any of ``METHODS_MOBILE``. The peer-encounter baselines
             (gossip/oppcl/mlmule+gossip) cross shards via the method
             table's ring ``ppermute`` exchange and are bitwise-equal to
             single host on a 1-device mesh under the default
             ``enc_backend="ref"`` (the ring always runs the ref block
             math — a single-host run on the Pallas backend agrees to
             kernel tolerance instead); blockwise accumulation order
             makes multi-shard gossip agree to float tolerance, while
             oppcl's peer pick is order-independent and stays bitwise.
    donate:  donate state buffers (in-place replay); input state is dead
             after the call.

    Returns ``(final_state, aux)`` exactly like ``run_population``.
    """
    if key is None:
        raise TypeError("run_population_distributed() missing required "
                        "argument: 'key'")
    fid, exch, pos, area, act = _colocation_tensors(colocation)
    n_steps = fid.shape[0]
    dcfg = _resolve_ring_bits(dcfg, jnp.max(area) if area.size else 0)
    rb = int(getattr(dcfg, "rebucket_every", 0) or 0)
    if rb > 0:
        # Re-bucketing swaps live state between chunks, so the materialized
        # run delegates to the streamed engine with one chunk per rebucket
        # window — streamed == materialized is pinned bitwise, so this is
        # the same replay with swap points inserted.
        if eval_fn is not None and eval_every and rb % eval_every:
            raise ValueError(
                f"rebucket_every={rb} must be a multiple of "
                f"eval_every={eval_every} so drift checks land on eval "
                "boundaries")
        from repro.mobility.streaming import compact_colocation
        return run_population_streamed(
            state, compact_colocation(colocation), batches, train_fn,
            dcfg.pop, key, n_steps=n_steps, chunk_len=rb,
            eval_every=eval_every, eval_fn=eval_fn, method=method,
            context=context, donate=donate, mesh=mesh, dcfg=dcfg)
    if mesh is None:
        mesh = _auto_mesh(method, fid.shape[1], dcfg)
    _check_mule_sharding(fid.shape[1], mesh, dcfg)
    stacked = None if callable(batches) else batches
    if jax.process_count() > 1:
        # multi-process mesh: commit every input explicitly so each
        # process materializes only its shard of the mule columns
        from jax.sharding import PartitionSpec as P
        from repro.launch.multiprocess import put_global, put_global_tree
        in_specs, _ = _distributed_specs(state, batches, dcfg, vmapped=False,
                                         area_dyn=area.ndim == 2)
        state = put_global_tree(state, mesh, in_specs[0])
        fid, exch, pos, area, act = (
            put_global(x, mesh, s) for x, s in
            zip((fid, exch, pos, area, act), in_specs[1:6]))
        if stacked is not None:
            stacked = put_global_tree(stacked, mesh, in_specs[6])
        if context is not None:
            context = put_global_tree(
                context, mesh, jax.tree.map(lambda _: P(), context))
        key = put_global(key, mesh, P())
    fn = get_compiled_replay(state, fid, exch, pos, area, act, batches,
                             context, key, train_fn, dcfg.pop, method=method,
                             eval_every=eval_every, eval_fn=eval_fn,
                             donate=donate, mesh=mesh, dcfg=dcfg)
    state, last, evals = fn(state, fid, exch, pos, area, act, stacked,
                            context, key)
    n_ev = n_steps // eval_every if (eval_fn is not None and eval_every) else 0
    steps = (np.arange(n_ev) + 1) * eval_every - 1 if n_ev else \
        np.zeros((0,), int)
    return state, {"last_fid": last, "eval_steps": steps, "evals": evals}


def run_population_distributed_loop(state: Dict[str, Any],
                                    colocation: Dict[str, Any], batches: Any,
                                    train_fn: TrainFn, dcfg, mesh, key, *,
                                    method: str = "mlmule",
                                    context: Any = None
                                    ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """Per-step distributed driver: the parity/bench reference.

    One jitted ``shard_map`` dispatch per simulation step — the dispatch
    pattern the deleted dense per-step engine imposed on every
    experiment, now driven through the same method-table step function
    and key discipline as the scan (the fused ``encounter_mix`` schedule
    is the only distributed encounter path), so
    ``run_population_distributed`` is pinned to it bitwise and the bench
    gap between the two is purely the dispatch tax. The jitted step is
    memoized in the engine jit cache, so repeat replays of the same
    signature dispatch warm.

    Returns ``(final_state, last_fid)`` (``last_fid`` sharded like the
    mule axis).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.distributed import make_distributed_method_step

    fid_T, exch_T, pos_T, area_A, act_T = _colocation_tensors(colocation)
    area_dyn = area_A.ndim == 2
    n_steps, n_mules = fid_T.shape
    _check_mule_sharding(n_mules, mesh, dcfg)
    ax = dcfg.data_axis
    state_specs = {
        k: jax.tree.map(lambda _: P(ax) if k in ("mule_models", "mule_ts")
                        else P(), v)
        for k, v in state.items()
    }
    info_specs = {"fixed_id": P(ax), "exchange": P(ax), "pos": P(ax),
                  "area": P(ax), "active": P(ax), "t": P()}
    cache_key = ("dist_loop_step", method, dcfg, mesh, train_fn,
                 _sig(state), area_dyn)
    step = _JIT_CACHE.get(cache_key)
    if step is None:
        _STATS["misses"] += 1
        step_core = make_distributed_method_step(method, train_fn, dcfg,
                                                 mesh=mesh)

        def counted(st, info, bt, k):
            _STATS["traces"] += 1      # python side effect: fires per trace
            return step_core(st, info, bt, k)

        step = jax.jit(shard_map(
            counted, mesh=mesh,
            in_specs=(state_specs, info_specs, P(), P()),
            out_specs=state_specs, check_rep=False))
        _JIT_CACHE[cache_key] = step
        while len(_JIT_CACHE) > _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
    else:
        _STATS["hits"] += 1
        _JIT_CACHE.move_to_end(cache_key)

    dynamic = callable(batches)
    last_fid = jnp.zeros((n_mules,), jnp.int32)
    for t in range(n_steps):
        fid, exch, pos, act = fid_T[t], exch_T[t], pos_T[t], act_T[t]
        if dynamic:
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            bt = batches(kb, t, context) if context is not None else \
                batches(kb, t)
        else:
            ks = jax.random.fold_in(key, t)
            bt = jax.tree.map(lambda l: l[t], batches)
        info = {"fixed_id": fid, "exchange": exch, "pos": pos,
                "area": area_A[t] if area_dyn else area_A,
                "active": act, "t": jnp.asarray(t, jnp.int32)}
        state = step(state, info, bt, ks)
        last_fid = jnp.where((fid >= 0) & act, fid, last_fid)
    return state, last_fid
