"""Compiled scenario engine: ``population_step`` under ``jax.lax.scan``.

The harness used to drive the simulation with a per-step Python loop — one
jitted dispatch per time step, thousands of dispatches per experiment. Here
the whole run is one (optionally chunked) ``lax.scan`` over precomputed
``[T, M]`` co-location tensors, with periodic evaluation *inside* the scan,
so a full scenario replay is a single XLA program.

Key discipline (the parity tests rely on reproducing it exactly):

- step ``t`` uses ``k_t = jax.random.fold_in(key, t)``;
- if ``batches`` is a callable ``(key, t) -> batches-dict``, the step splits
  ``kb, ks = jax.random.split(k_t)`` and calls ``batches(kb, t)``; the
  training key is ``ks``;
- if ``batches`` is a pytree of stacked ``[T, ...]`` leaves, step ``t``
  consumes slice ``t`` and trains with ``k_t`` directly.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.population import PopulationConfig, TrainFn, population_step


def run_population(state: Dict[str, Any], colocation: Dict[str, Any],
                   batches: Any, train_fn: TrainFn, cfg: PopulationConfig,
                   key, *, eval_every: Optional[int] = None,
                   eval_fn: Optional[Callable[[Dict[str, Any], jnp.ndarray],
                                              Any]] = None
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Scan ``population_step`` over a precomputed co-location schedule.

    state:      population state from ``init_population``.
    colocation: {"fixed_id": [T, M] int32 (-1 = corridor),
                 "exchange": [T, M] bool} (extra keys ignored).
    batches:    callable ``(key, t) -> {"fixed": ..., "mule": ...}`` sampled
                inside the scan (traceable), or a pytree of stacked
                ``[T, ...]`` leaves consumed as scan inputs.
    eval_fn:    optional traceable ``(state, last_fid [M]) -> metric pytree``
                run inside the scan every ``eval_every`` steps (``last_fid``
                is each mule's most recent fixed device, 0 before any visit).

    Returns ``(final_state, aux)`` with
    ``aux = {"last_fid": [M], "eval_steps": np [E], "evals": stacked/None}``
    where eval step ``i`` is taken after step ``(i+1)*eval_every - 1``.
    """
    fid = jnp.asarray(np.asarray(colocation["fixed_id"]), jnp.int32)
    exch = jnp.asarray(np.asarray(colocation["exchange"]), bool)
    n_steps, n_mules = fid.shape
    dynamic_batches = callable(batches)
    ts = jnp.arange(n_steps, dtype=jnp.int32)

    def body(carry, xs):
        st, last = carry
        if dynamic_batches:
            fid_t, exch_t, t = xs
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            bt = batches(kb, t)
        else:
            fid_t, exch_t, t, bt = xs
            ks = jax.random.fold_in(key, t)
        st = population_step(st, {"fixed_id": fid_t, "exchange": exch_t},
                             bt, train_fn, cfg, ks)
        last = jnp.where(fid_t >= 0, fid_t, last)
        return (st, last), None

    def xs_slice(lo, hi):
        xs = (fid[lo:hi], exch[lo:hi], ts[lo:hi])
        if not dynamic_batches:
            xs = xs + (jax.tree.map(lambda l: l[lo:hi], batches),)
        return xs

    carry = (state, jnp.zeros((n_mules,), jnp.int32))

    if eval_fn is None or not eval_every:
        carry, _ = jax.lax.scan(body, carry, xs_slice(0, n_steps))
        (state, last) = carry
        return state, {"last_fid": last, "eval_steps": np.zeros((0,), int),
                       "evals": None}

    n_ev = n_steps // eval_every

    def chunk(carry, xs):
        carry, _ = jax.lax.scan(body, carry, xs)
        st, last = carry
        return carry, eval_fn(st, last)

    head = jax.tree.map(
        lambda l: l[: n_ev * eval_every].reshape(
            (n_ev, eval_every) + l.shape[1:]), xs_slice(0, n_steps))
    carry, evals = jax.lax.scan(chunk, carry, head)
    if n_ev * eval_every < n_steps:                  # trailing partial chunk
        carry, _ = jax.lax.scan(body, carry,
                                xs_slice(n_ev * eval_every, n_steps))
    (state, last) = carry
    steps = (np.arange(n_ev) + 1) * eval_every - 1
    return state, {"last_fid": last, "eval_steps": steps, "evals": evals}
