"""Scenario registry: name -> (mobility generator x protocol mode x data
partition).

A scenario bundles everything the harness needs to replay one workload:
how mules move (a co-location schedule builder), which side trains
(``mode``), and how data lands on devices (``dist``/``task`` strings the
partitioners in ``benchmarks/common.py`` understand). Benchmarks and
examples select scenarios by string — adding a workload is one
``register()`` call, not a new driver.

Co-location builders return numpy arrays:
  fixed_id  [T, M] int32   co-located fixed device per mule (-1 = none)
  exchange  [T, M] bool    completed-exchange flags
  pos       [T, M, 2] f32  positions (zeros for check-in traces)
  area      [M] int32      each mule's area — or [T, M] int32 when mules
                           migrate between areas (the migratory scenarios;
                           the engines thread the current row per step)
  active    [T, M] bool    churn mask (optional; absent == dense)
  init_space/init_area [M] initial space/area (seeds the data partition)

Churn and heterogeneous spaces are declarative: a ``ChurnSpec`` on the
scenario picks one of the ``repro.mobility`` mask generators (``register``
folds the mask into every build), and a tuple of ``SpaceSpec`` gives each
space its own exchange tempo, folded into the trace expansion's dwell
cadence.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.mobility import (MobilityConfig, commuter_stream, commuter_trace,
                            compact_colocation, duty_cycle_mask,
                            dwell_exchange_flags, event_crowd_trace,
                            flash_churn_mask, init_mobility,
                            markov_churn_mask, materialize_generator,
                            multi_area_trace, shift_worker_trace,
                            simulate_trajectories, space_of,
                            synth_foursquare_trace, trace_to_colocation)

Colocation = Dict[str, np.ndarray]

_CHURN_GENERATORS = {
    "markov": markov_churn_mask,
    "flash": flash_churn_mask,
    "duty_cycle": duty_cycle_mask,
}


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Declarative population churn: which mask generator, with what knobs.

    ``kind`` selects from ``repro.mobility``'s generators (markov | flash |
    duty_cycle); ``params`` are its keyword arguments. ``seed_offset``
    decorrelates the mask draw from the mobility draw of the same scenario
    seed while keeping builds deterministic per seed.
    """
    kind: str = "markov"
    params: Tuple[Tuple[str, float], ...] = ()
    seed_offset: int = 7919

    def mask(self, seed: int, n_steps: int, n_mules: int) -> np.ndarray:
        if self.kind not in _CHURN_GENERATORS:
            raise ValueError(f"unknown churn kind {self.kind!r}; expected "
                             f"one of {sorted(_CHURN_GENERATORS)}")
        return _CHURN_GENERATORS[self.kind](seed + self.seed_offset, n_steps,
                                            n_mules, **dict(self.params))


@dataclasses.dataclass(frozen=True)
class SpaceSpec:
    """Per-space knobs folded into the colocation build.

    ``exchange_steps`` is this space's exchange tempo — how many
    consecutive dwell steps complete one model hand-off (the homogeneous
    engines hardcoded 3 everywhere).
    """
    exchange_steps: int = 3


def _cadence(spaces: Tuple[SpaceSpec, ...]):
    """Per-place exchange_steps array for ``trace_colocation`` (or 3)."""
    if not spaces:
        return 3
    return np.array([sp.exchange_steps for sp in spaces], np.int64)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    colocation: Callable[..., Colocation]   # (seed, n_mules, n_steps) -> dict
    mode: str = "mobile"                    # which side trains (fixed|mobile)
    dist: str = "shards"                    # data partition selector
    task: str = "image"                     # image | har
    n_fixed: int = 8                        # spaces (= valid fixed ids)
    churn: Optional[ChurnSpec] = None       # device join/leave mask
    spaces: Tuple[SpaceSpec, ...] = ()      # per-space exchange tempos
    # native chunk generator (seed, n_mules, n_steps) -> ChunkGenerator for
    # run_population_streamed; scenarios without one stream through
    # compact_colocation (see scenario_generator)
    generator: Optional[Callable] = None
    description: str = ""


SCENARIOS: Dict[str, ScenarioSpec] = {}


def _folded(build: Callable[..., Colocation], churn: Optional[ChurnSpec],
            spaces: Tuple[SpaceSpec, ...]) -> Callable[..., Colocation]:
    """Wrap a builder so the spec's churn/space declarations take effect."""
    def with_spec(seed: int, n_mules: int, n_steps: int) -> Colocation:
        co = build(seed, n_mules, n_steps)
        if spaces:
            co["exchange"] = dwell_exchange_flags(
                np.asarray(co["fixed_id"]), _cadence(spaces))
        if churn is not None:
            co["active"] = churn.mask(seed, n_steps, n_mules)
        return co
    return with_spec


def register(spec: ScenarioSpec) -> ScenarioSpec:
    """Add a scenario; declared ``churn``/``spaces`` fold into every build
    (the mask is generated, and exchange flags are re-derived from dwell
    runs under the per-space tempos), so the declarations on the spec are
    the single source of truth."""
    if spec.churn is not None or spec.spaces:
        spec = dataclasses.replace(
            spec, colocation=_folded(spec.colocation, spec.churn,
                                     spec.spaces))
    SCENARIOS[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; available: "
                         f"{', '.join(list_scenarios())}")
    return SCENARIOS[name]


def list_scenarios():
    return sorted(SCENARIOS)


def scenario_generator(name_or_spec, seed: int, n_mules: int, n_steps: int,
                       colocation: Optional[Colocation] = None):
    """Chunk generator for a scenario — native or compacted.

    A spec with a native ``generator`` (procedural, O(M) memory, any
    horizon) builds it directly. Every other scenario streams through
    :func:`repro.mobility.compact_colocation`: its materialized schedule
    (pass ``colocation`` to reuse one already built, else the spec builds
    it here) is losslessly RLE-compacted, with the spec's per-space tempos
    as the dwell cadence — so the on-device expansion is bitwise-equal to
    the host tensors for *every* registered scenario.
    """
    spec = name_or_spec if isinstance(name_or_spec, ScenarioSpec) \
        else get_scenario(name_or_spec)
    if spec.generator is not None:
        return spec.generator(seed, n_mules, n_steps)
    if colocation is None:
        colocation = spec.colocation(seed, n_mules, n_steps)
    return compact_colocation(colocation, cadence=_cadence(spec.spaces))


# ---------------------------------------------------------------------------
# co-location builders
# ---------------------------------------------------------------------------


def walk_colocation(seed: int, n_mules: int, n_steps: int,
                    p_cross: float = 0.1) -> Colocation:
    """Unroll the random-walk mobility model into [T, M] tensors (one scan).

    ``simulate_trajectories`` re-derives the same initial state from the
    same key, so the separate ``init_mobility`` call below only recovers
    the step-0 space/area for the data partition.
    """
    mcfg = MobilityConfig(n_mules=n_mules, p_cross=p_cross)
    state = init_mobility(jax.random.PRNGKey(seed), mcfg)
    infos = simulate_trajectories(jax.random.PRNGKey(seed), mcfg, n_steps)
    area = np.asarray(state["area"], np.int32)
    return {
        "fixed_id": np.asarray(infos["fixed_id"], np.int32),
        "exchange": np.asarray(infos["exchange"], bool),
        "pos": np.asarray(infos["pos"], np.float32),
        "area": area,
        "init_space": np.asarray(space_of(state["pos"],
                                          mcfg.space_size)).clip(0),
        "init_area": area.copy(),
    }


def trace_colocation(visits: np.ndarray, n_mules: int,
                     n_steps: int) -> Colocation:
    """Expand a (user, place, t_in, t_out) visit log into engine tensors.

    Heterogeneous space tempos are a *scenario* declaration: ``register``
    re-derives the exchange flags from the spec's ``SpaceSpec`` tuple
    (``dwell_exchange_flags``), so the expansion here always uses the
    homogeneous default cadence.
    """
    fid, exch = trace_to_colocation(visits, n_mules, n_steps)
    present = fid >= 0
    any_visit = present.any(axis=0)
    first_t = present.argmax(axis=0)
    first = np.where(any_visit, fid[first_t, np.arange(n_mules)], 0)
    return {
        "fixed_id": fid,
        "exchange": exch,
        "pos": np.zeros((n_steps, n_mules, 2), np.float32),
        "area": (fid.max(axis=0).clip(0) // 4).astype(np.int32),
        "init_space": (first % 4).astype(np.int64),
        "init_area": (first // 4).astype(np.int64),
    }


def _from_trace(gen: Callable[..., np.ndarray], n_places: int = 8, **gen_kw):
    def build(seed: int, n_mules: int, n_steps: int) -> Colocation:
        visits = gen(seed, n_users=n_mules, n_places=n_places,
                     n_steps=n_steps, **gen_kw)
        return trace_colocation(visits, n_mules, n_steps)
    return build


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------

register(ScenarioSpec(
    name="random_walk", colocation=walk_colocation,
    mode="fixed", dist="dir0.01",
    description="Paper Sec 4.1/4.2: random walk with P_cross=0.1, smart-space "
                "devices train on Dirichlet(0.01) partitions (Table 1)."))

register(ScenarioSpec(
    name="foursquare_sparse",
    colocation=_from_trace(synth_foursquare_trace),
    mode="mobile", dist="shards",
    description="Paper '4Q' condition: sparse Foursquare-style check-ins, "
                "mules train on shard data of their home space (Fig 6-7)."))

register(ScenarioSpec(
    name="commuter", colocation=_from_trace(commuter_trace),
    mode="mobile", dist="shards",
    description="Daily home/work oscillation — dense periodic co-location."))

register(ScenarioSpec(
    name="shift_worker", colocation=_from_trace(shift_worker_trace),
    mode="mobile", dist="shards",
    description="Rotating crews hand models across workplaces shift by shift."))

register(ScenarioSpec(
    name="event_crowd", colocation=_from_trace(event_crowd_trace),
    mode="mobile", dist="shards",
    description="Sparse background plus mass events: bursts of simultaneous "
                "deliveries stress freshness filtering and aggregation."))


# -- churn / heterogeneous-space scenarios ----------------------------------

register(ScenarioSpec(
    name="commuter_churn", colocation=_from_trace(commuter_trace),
    mode="mobile", dist="shards",
    churn=ChurnSpec(kind="markov",
                    params=(("p_leave", 0.04), ("p_join", 0.10))),
    description="Commuter mobility with session churn: devices drop off and "
                "rejoin in geometric sessions (Markov on/off), so delivery "
                "schedules thin out unpredictably mid-run."))

register(ScenarioSpec(
    name="event_crowd_flash", colocation=_from_trace(event_crowd_trace),
    mode="mobile", dist="shards",
    churn=ChurnSpec(kind="flash",
                    params=(("n_flashes", 4), ("flash_len", 40),
                            ("base_frac", 0.25), ("join_frac", 0.9))),
    description="Event crowds whose devices are only awake around events: "
                "flash joins at each venue window, mass exits when it "
                "closes, a small always-on core in between."))

register(ScenarioSpec(
    name="multi_area_3city",
    colocation=_from_trace(multi_area_trace, n_places=12, n_areas=3),
    mode="mobile", dist="shards", n_fixed=12,
    description="Three near-isolated cities (12 spaces, 3 areas) with rare "
                "cross-city travelers: affinity groups must form per city "
                "without cross-area leakage."))


def _migratory_colocation(seed: int, n_mules: int, n_steps: int) -> Colocation:
    """3-city trace with heavy travel and a *time-varying* area column.

    ``p_travel=0.25`` makes relocation the norm, and ``area_over_time``
    replaces the static per-mule area with the ``[T, M]`` trace of each
    mule's current city — the workload whose build-time bucketing decays
    and mid-run re-bucketing (``DistributedConfig.rebucket_every``) exists
    to fix.
    """
    from repro.mobility import area_over_time
    co = _from_trace(multi_area_trace, n_places=12, n_areas=3,
                     p_travel=0.25)(seed, n_mules, n_steps)
    co["area"] = area_over_time(co["fixed_id"], co["init_area"])
    return co


register(ScenarioSpec(
    name="multi_area_migratory",
    colocation=_migratory_colocation,
    mode="mobile", dist="shards", n_fixed=12,
    description="Three cities with heavy migration (p_travel=0.25) and a "
                "time-varying [T, M] area column: mules relocate for good, "
                "so shard/area alignment decays unless the distributed ring "
                "re-buckets mid-run."))

# -- HAR task variants -------------------------------------------------------
# Same mobility as the image-task trace scenarios, but the harness binds
# the paper's LSTM-CNN HAR stack (task="har" selects the IMU dataset and
# ``repro.configs.mule_lstm_cnn`` data shapes — Fig 8/9's model) instead of
# the CNN/CIFAR-like pipeline, so sequence models ride every engine path.

register(ScenarioSpec(
    name="har_commuter", colocation=_from_trace(commuter_trace),
    mode="mobile", dist="shards", task="har",
    description="Fig 8's IMU HAR task under commuter mobility: LSTM-CNN "
                "models hand across home/work spaces each day."))

register(ScenarioSpec(
    name="har_shift_worker", colocation=_from_trace(shift_worker_trace),
    mode="mobile", dist="shards", task="har",
    description="IMU HAR with rotating crews: LSTM-CNN models relay "
                "between workplaces shift by shift."))


# -- streaming-native scenarios ---------------------------------------------

def _streaming_commuter_colocation(seed: int, n_mules: int,
                                   n_steps: int) -> Colocation:
    """Materialized reference of the procedural commuter stream.

    The generator is the source of truth; this builder expands it so every
    materialized engine path (and the parity tests) sees the identical
    schedule the streamed replay generates on device.
    """
    return materialize_generator(commuter_stream(seed, n_mules, n_steps))


register(ScenarioSpec(
    name="streaming_commuter",
    colocation=_streaming_commuter_colocation,
    mode="mobile", dist="shards",
    generator=commuter_stream,
    description="Procedural commuter schedule generated inside the compiled "
                "scan (per-mule home/work/jitter params, O(M) memory at any "
                "horizon) — the native workload of run_population_streamed "
                "and the M=10^5+ scale sweep."))


register(ScenarioSpec(
    name="mixed_cadence",
    colocation=_from_trace(commuter_trace),
    mode="mobile", dist="shards",
    spaces=tuple(SpaceSpec(exchange_steps=s)
                 for s in (1, 2, 4, 8, 3, 6, 2, 5)),
    description="Heterogeneous exchange tempos: each space completes a "
                "hand-off in its own number of dwell steps (1..8), so "
                "fast kiosks and slow galleries coexist in one run."))
