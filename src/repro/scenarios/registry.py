"""Scenario registry: name -> (mobility generator x protocol mode x data
partition).

A scenario bundles everything the harness needs to replay one workload:
how mules move (a co-location schedule builder), which side trains
(``mode``), and how data lands on devices (``dist``/``task`` strings the
partitioners in ``benchmarks/common.py`` understand). Benchmarks and
examples select scenarios by string — adding a workload is one
``register()`` call, not a new driver.

Co-location builders return numpy arrays:
  fixed_id  [T, M] int32   co-located fixed device per mule (-1 = none)
  exchange  [T, M] bool    completed-exchange flags
  pos       [T, M, 2] f32  positions (zeros for check-in traces)
  area      [M] int32      each mule's area (constant; areas are isolated)
  init_space/init_area [M] initial space/area (seeds the data partition)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import numpy as np

from repro.mobility import (MobilityConfig, commuter_trace, event_crowd_trace,
                            init_mobility, shift_worker_trace,
                            simulate_trajectories, space_of,
                            synth_foursquare_trace, trace_to_colocation)

Colocation = Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    colocation: Callable[..., Colocation]   # (seed, n_mules, n_steps) -> dict
    mode: str = "mobile"                    # which side trains (fixed|mobile)
    dist: str = "shards"                    # data partition selector
    task: str = "image"                     # image | har
    description: str = ""


SCENARIOS: Dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    SCENARIOS[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {', '.join(list_scenarios())}")
    return SCENARIOS[name]


def list_scenarios():
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# co-location builders
# ---------------------------------------------------------------------------


def walk_colocation(seed: int, n_mules: int, n_steps: int,
                    p_cross: float = 0.1) -> Colocation:
    """Unroll the random-walk mobility model into [T, M] tensors (one scan).

    ``simulate_trajectories`` re-derives the same initial state from the
    same key, so the separate ``init_mobility`` call below only recovers
    the step-0 space/area for the data partition.
    """
    mcfg = MobilityConfig(n_mules=n_mules, p_cross=p_cross)
    state = init_mobility(jax.random.PRNGKey(seed), mcfg)
    infos = simulate_trajectories(jax.random.PRNGKey(seed), mcfg, n_steps)
    area = np.asarray(state["area"], np.int32)
    return {
        "fixed_id": np.asarray(infos["fixed_id"], np.int32),
        "exchange": np.asarray(infos["exchange"], bool),
        "pos": np.asarray(infos["pos"], np.float32),
        "area": area,
        "init_space": np.asarray(space_of(state["pos"],
                                          mcfg.space_size)).clip(0),
        "init_area": area.copy(),
    }


def trace_colocation(visits: np.ndarray, n_mules: int,
                     n_steps: int) -> Colocation:
    """Expand a (user, place, t_in, t_out) visit log into engine tensors."""
    fid, exch = trace_to_colocation(visits, n_mules, n_steps)
    present = fid >= 0
    any_visit = present.any(axis=0)
    first_t = present.argmax(axis=0)
    first = np.where(any_visit, fid[first_t, np.arange(n_mules)], 0)
    return {
        "fixed_id": fid,
        "exchange": exch,
        "pos": np.zeros((n_steps, n_mules, 2), np.float32),
        "area": (fid.max(axis=0).clip(0) // 4).astype(np.int32),
        "init_space": (first % 4).astype(np.int64),
        "init_area": (first // 4).astype(np.int64),
    }


def _from_trace(gen: Callable[..., np.ndarray], **gen_kw):
    def build(seed: int, n_mules: int, n_steps: int) -> Colocation:
        visits = gen(seed, n_users=n_mules, n_places=8, n_steps=n_steps,
                     **gen_kw)
        return trace_colocation(visits, n_mules, n_steps)
    return build


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------

register(ScenarioSpec(
    name="random_walk", colocation=walk_colocation,
    mode="fixed", dist="dir0.01",
    description="Paper Sec 4.1/4.2: random walk with P_cross=0.1, smart-space "
                "devices train on Dirichlet(0.01) partitions (Table 1)."))

register(ScenarioSpec(
    name="foursquare_sparse",
    colocation=_from_trace(synth_foursquare_trace),
    mode="mobile", dist="shards",
    description="Paper '4Q' condition: sparse Foursquare-style check-ins, "
                "mules train on shard data of their home space (Fig 6-7)."))

register(ScenarioSpec(
    name="commuter", colocation=_from_trace(commuter_trace),
    mode="mobile", dist="shards",
    description="Daily home/work oscillation — dense periodic co-location."))

register(ScenarioSpec(
    name="shift_worker", colocation=_from_trace(shift_worker_trace),
    mode="mobile", dist="shards",
    description="Rotating crews hand models across workplaces shift by shift."))

register(ScenarioSpec(
    name="event_crowd", colocation=_from_trace(event_crowd_trace),
    mode="mobile", dist="shards",
    description="Sparse background plus mass events: bursts of simultaneous "
                "deliveries stress freshness filtering and aggregation."))
