"""Batched sweeps: one vmapped compiled replay per (method, shape) cell.

The paper's headline results (Figs 6-9, Table 1) are seed-averaged curves
across five methods. Running them as S x 5 independent ``run_population``
calls pays Python dispatch and (without the jit cache) a retrace per cell;
``run_sweep`` instead vmaps the scan over a stacked seed axis so the whole
seed batch is ONE XLA program executed once — the same
amortize-across-clients lever FedAvg-style simulators use.

Batching rules:

- **Seeds vmap.** Everything seed-dependent is stacked on a leading ``[S]``
  axis: population states, colocation tensors, PRNG keys, the optional
  ``context`` pytree (per-seed datasets), and stacked-batch leaves
  (``[S, T, ...]``). ``stack_trees`` builds these stacks.
- **Methods loop.** Two methods can only share a vmapped program when
  their step pytrees AND step computations coincide; the five
  ``METHODS_MOBILE`` all differ in computation (different update rules,
  conditional cadences), so methods run as separate compiled programs.
  The engine's jit cache still amortizes them: each method compiles once
  per shape signature for the life of the process, and the vmapped seed
  batch rides inside each.

Bitwise guarantee (pinned by ``tests/test_sweep.py``): lane ``i`` of a
vmapped sweep equals the ``i``-th sequential ``run_population`` call — the
engine's fold_in/split key discipline is elementwise, and XLA's batched
lowering preserves per-lane numerics on CPU.

``run_sweep_distributed`` composes the seed axis with the distributed
engine's mesh: the seed ``vmap`` sits *inside* the ``shard_map`` block —
stacked outside the sharded mule axis, unsharded — so a distributed
multi-seed sweep is still one program per method, and each lane is
bitwise-equal to a sequential ``run_population_distributed`` call on the
same mesh (``tests/test_distributed.py`` pins it). All five
``METHODS_MOBILE`` sweep distributed: the peer-encounter baselines' ring
``ppermute`` exchange batches under the seed vmap like any other
collective (``tests/test_distributed_engine.py`` pins a gossip lane).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.population import PopulationConfig, TrainFn
from repro.scenarios.engine import _colocation_tensors, get_compiled_replay

SweepResult = Tuple[Dict[str, Any], Dict[str, Any]]


def stack_trees(trees: Sequence[Any]) -> Any:
    """Stack a list of same-structure pytrees along a new leading axis."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def stack_colocations(cos: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Stack per-seed colocation dicts into [S, T, M] engine tensors.

    The churn mask stacks too (``active`` [S, T, M]); seeds without one
    stack as all-ones lanes, so dense and churned seeds can share a sweep.
    """
    per = [_colocation_tensors(co) for co in cos]
    fid, exch, pos, area, act = (stack_trees([p[i] for p in per])
                                 for i in range(5))
    return {"fixed_id": fid, "exchange": exch, "pos": pos, "area": area,
            "active": act}


def run_sweep(states: Dict[str, Any], colocations: Dict[str, Any],
              batches: Any, train_fn: TrainFn, cfg: PopulationConfig,
              keys, *, eval_every: Optional[int] = None,
              eval_fn: Optional[Callable] = None,
              methods: Union[str, Sequence[str]] = "mlmule",
              context: Any = None, mesh=None, dcfg=None,
              donate: bool = False
              ) -> Union[SweepResult, Dict[str, SweepResult]]:
    """Replay S seeds (x several methods) as vmapped compiled scans.

    states:      population states stacked ``[S, ...]`` (``stack_trees``
                 over per-seed ``init_population`` results).
    colocations: colocation dict with ``[S, T, M]`` tensors
                 (``stack_colocations``), or a single unstacked ``[T, M]``
                 dict shared by every seed (broadcast here). A per-seed
                 ``"active"`` churn mask vmaps with the rest (absent ==
                 dense).
    batches:     traceable callable ``(key, t[, context]) -> batch dict``
                 (shared code; per-seed data goes through ``context``), or
                 a pytree of ``[S, T, ...]`` stacked leaves.
    keys:        stacked PRNG keys ``[S, 2]``.
    context:     optional pytree stacked ``[S, ...]`` handed to ``batches``
                 / ``eval_fn`` as a trailing arg — per-seed datasets.
    methods:     one method name or a sequence of them.
    mesh/dcfg:   distributed mode (``run_sweep_distributed`` fills these):
                 each lane replays on the mule-sharded engine.
    donate:      donate the stacked state buffers (single method only —
                 a second method would replay already-donated state).

    Returns ``(final_states, aux)`` with every array carrying a leading
    ``[S]`` axis (``aux["evals"]`` is ``[S, E, ...]``); for a sequence of
    methods, a ``{method: (final_states, aux)}`` dict.
    """
    import jax.numpy as jnp
    if donate and not isinstance(methods, str):
        raise ValueError("donate=True replays would reuse donated state "
                         "across methods; pass a single method")
    fid, exch, pos, area, act = _colocation_tensors(colocations)
    if fid.ndim == 2:                      # shared schedule -> broadcast
        s = jax.tree.leaves(keys)[0].shape[0]
        fid, exch, pos, area, act = (jnp.broadcast_to(l, (s,) + l.shape)
                                     for l in (fid, exch, pos, area, act))
    n_steps = int(fid.shape[1])
    if mesh is not None:
        from repro.scenarios.engine import _check_mule_sharding
        _check_mule_sharding(int(fid.shape[2]), mesh, dcfg)
    stacked = None if callable(batches) else batches

    def one(method: str) -> SweepResult:
        fn = get_compiled_replay(states, fid, exch, pos, area, act, batches,
                                 context, keys, train_fn, cfg, method=method,
                                 eval_every=eval_every, eval_fn=eval_fn,
                                 vmapped=True, donate=donate, mesh=mesh,
                                 dcfg=dcfg)
        final, last, evals = fn(states, fid, exch, pos, area, act, stacked,
                                context, keys)
        n_ev = (n_steps // eval_every
                if (eval_fn is not None and eval_every) else 0)
        steps = (np.arange(n_ev) + 1) * eval_every - 1 if n_ev else \
            np.zeros((0,), int)
        return final, {"last_fid": last, "eval_steps": steps,
                       "evals": evals}

    if isinstance(methods, str):
        return one(methods)
    return {m: one(m) for m in methods}


def run_sweep_distributed(states: Dict[str, Any], colocations: Dict[str, Any],
                          batches: Any, train_fn: TrainFn, dcfg, mesh,
                          keys, *, eval_every: Optional[int] = None,
                          eval_fn: Optional[Callable] = None,
                          methods: Union[str, Sequence[str]] = "mlmule",
                          context: Any = None, donate: bool = False
                          ) -> Union[SweepResult, Dict[str, SweepResult]]:
    """``run_sweep`` on the mule-sharded distributed engine.

    Same stacking contract as ``run_sweep`` (leading ``[S]`` seed axis on
    states/colocations/keys/context), plus ``dcfg``/``mesh`` from
    ``run_population_distributed``; states follow the
    ``to_distributed_state`` layout, stacked. The seed axis vmaps *inside*
    the ``shard_map`` block (unsharded, outside the mule axis), so the
    whole distributed sweep is one compiled program per method and lane
    ``i`` is bitwise-equal to the ``i``-th sequential
    ``run_population_distributed`` call. ``methods`` accepts any of the
    five ``METHODS_MOBILE`` — the peer-encounter baselines ride their ring
    exchange inside the vmapped scan.
    """
    return run_sweep(states, colocations, batches, train_fn, dcfg.pop, keys,
                     eval_every=eval_every, eval_fn=eval_fn,
                     methods=methods, context=context, mesh=mesh, dcfg=dcfg,
                     donate=donate)
