"""Roofline-driven autotuning over the compiled scenario engine.

Two halves, one artifact:

**Engine roofline.** ``analyze_engine_step`` compiles the scenario-engine
replay (``repro.scenarios.engine.get_compiled_replay`` — the exact program
experiments run, single-host or mule-sharded) for one (method × M × mesh)
cell, feeds the compiled HLO through the scan-aware analyzer
(``repro.launch.hlo_analysis``), and returns the three roofline terms in
seconds (compute / memory / collective against the per-chip peaks in
``repro.launch.roofline``) plus the dominant term. ``roofline_sweep`` runs
the grid of cells and is what ``benchmarks/engine_micro.py --roofline``
records.

**Kernel tuning.** ``tune_mule_agg`` / ``tune_encounter_mix`` generalize the
old hand table in ``repro.kernels.mule_agg.ops`` (one measured constant) and
the hand defaults in ``encounter_mix``: every candidate block size that fits
the VMEM residency model (tile footprints priced via the shared dtype table)
is timed on this container's interpret path — which tracks *relative* block
behaviour, not TPU latency, exactly like the retired
``kernels_micro.run_block_d_sweep`` — and the argmin wins. Selections land
in the tuning cache section of ``benchmarks/BENCH_roofline.json``; the
kernel wrappers look their block sizes up there at call time
(``tuned_block_d`` / ``tuned_encounter_blocks``) and fall back to the old
hand defaults when the cache is absent.

``REPRO_TUNE_CACHE`` points the lookup at a different cache file (tests use
it; an empty value disables the cache entirely). ``REPRO_PALLAS_INTERPRET``
keeps its meaning in the kernel wrappers — tuning never touches it.

The committed artifact is a *ratchet*: ``benchmarks/bench_gate.py`` validates
its schema on every tier-1 push and fails the CI slow lane if a freshly
produced artifact's headline metric (``tuned_speedup_vs_default`` — how much
the measured selection beats the static defaults) regresses past the
threshold. See ``benchmarks/README.md``.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# tuning cache: the runtime half (no jax import needed to look up a block)
# ---------------------------------------------------------------------------

_CACHE_PATH_ENV = "REPRO_TUNE_CACHE"
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_CACHE_PATH = os.path.join(_REPO_ROOT, "benchmarks",
                                  "BENCH_roofline.json")

_UNSET = object()
_cache_memo: Any = _UNSET

# VMEM residency budget for candidate feasibility (one v5e core; the model
# prices the per-grid-step tile working set, not whole-array HBM footprints)
VMEM_BUDGET_BYTES = 16 * 2 ** 20

MULE_AGG_BLOCK_D_CANDIDATES = (256, 512, 1024, 2048, 4096)
ENCOUNTER_BLOCK_M_CANDIDATES = (128, 256, 512)
ENCOUNTER_BLOCK_D_CANDIDATES = (256, 512, 1024, 2048)

# the pre-tuning hand values the lookups fall back to (and the baseline the
# headline metric is measured against)
MULE_AGG_DEFAULT_BLOCK_D = 4096
ENCOUNTER_DEFAULT_BLOCKS = (256, 2048)


def tuning_cache_clear() -> None:
    """Drop the memoized cache (tests repoint ``REPRO_TUNE_CACHE``)."""
    global _cache_memo
    _cache_memo = _UNSET


def load_tuning_cache(path: Optional[str] = None) -> Optional[Dict]:
    """The parsed tuning cache, or ``None`` when unavailable.

    Resolution order: explicit ``path`` > ``REPRO_TUNE_CACHE`` (empty value
    disables) > the committed ``benchmarks/BENCH_roofline.json``. The
    default resolution is memoized; a malformed or missing file reads as
    "no cache" — autotuning must never be able to break a kernel call.
    """
    global _cache_memo
    if path is None and _cache_memo is not _UNSET:
        return _cache_memo
    resolved = path
    if resolved is None:
        resolved = os.environ.get(_CACHE_PATH_ENV)
        if resolved == "":
            _cache_memo = None
            return None
        if resolved is None:
            resolved = DEFAULT_CACHE_PATH
    try:
        with open(resolved) as f:
            cache = json.load(f)
        if not isinstance(cache.get("tuned"), dict):
            cache = None
    except (OSError, ValueError):
        cache = None
    if path is None:
        _cache_memo = cache
    return cache


def _nearest(entries: List[Dict], query: Dict[str, int]) -> Optional[Dict]:
    """Entry minimizing the summed |log shape ratio| over the query dims."""
    best, best_cost = None, None
    for e in entries:
        try:
            cost = sum(abs(math.log(max(int(e[k]), 1) / max(int(v), 1)))
                       for k, v in query.items())
        except (KeyError, TypeError, ValueError):
            continue
        if best_cost is None or cost < best_cost:
            best, best_cost = e, cost
    return best


def tuned_block_d(d: int,
                  default: int = MULE_AGG_DEFAULT_BLOCK_D) -> int:
    """``mule_agg`` D-tile size for a [M, D] population: the measured
    selection of the nearest tuned shape, else ``default``."""
    cache = load_tuning_cache()
    if cache:
        e = _nearest(cache["tuned"].get("mule_agg", []), {"d": d})
        if e and isinstance(e.get("block_d"), int):
            return e["block_d"]
    return default


def tuned_encounter_blocks(
        m: int, d: int,
        default: Tuple[int, int] = ENCOUNTER_DEFAULT_BLOCKS
) -> Tuple[int, int]:
    """``encounter_mix`` (block_m, block_d) for an [M, D] population."""
    cache = load_tuning_cache()
    if cache:
        e = _nearest(cache["tuned"].get("encounter_mix", []),
                     {"m": m, "d": d})
        if (e and isinstance(e.get("block_m"), int)
                and isinstance(e.get("block_d"), int)):
            return e["block_m"], e["block_d"]
    return default


def suggest_mesh_shape(method: str, n_mules: int,
                       path: Optional[str] = None
                       ) -> Optional[Tuple[int, int]]:
    """(pod, data) mesh shape minimizing collective+memory roofline seconds.

    Scans the cache's mesh rows (``roofline`` entries with an ``AxB`` mesh
    string — the distributed cells ``roofline_sweep`` records per shape),
    keeps the rows for ``method`` when any exist (else all mesh rows),
    takes each shape's nearest-``n_mules`` row, and returns the shape whose
    per-step ``t_collective + t_memory`` is smallest — the two terms the
    mesh shape actually moves (compute per device is shape-invariant at
    fixed chip count). Returns ``None`` without a usable cache, exactly
    like the block-size lookups: callers must keep their own fallback.
    """
    cache = load_tuning_cache(path)
    if not cache:
        return None
    rows = [r for r in cache.get("roofline", [])
            if isinstance(r, dict) and isinstance(r.get("mesh"), str)
            and "x" in r["mesh"]]
    mine = [r for r in rows if r.get("method") == method] or rows
    by_shape: Dict[str, List[Dict]] = {}
    for r in mine:
        by_shape.setdefault(r["mesh"], []).append(r)
    best, best_cost = None, None
    for shape, entries in by_shape.items():
        e = _nearest(entries, {"n_mules": n_mules})
        if e is None:
            continue
        try:
            cost = (float(e["t_collective_us_per_step"])
                    + float(e["t_memory_us_per_step"]))
            dims = tuple(int(x) for x in shape.split("x"))
        except (KeyError, TypeError, ValueError):
            continue
        if len(dims) != 2 or any(x < 1 for x in dims):
            continue
        if best_cost is None or cost < best_cost:
            best, best_cost = dims, cost
    return best


# ---------------------------------------------------------------------------
# VMEM feasibility model (per-grid-step tile working set, f32 accumulators)
# ---------------------------------------------------------------------------


def mule_agg_tile_bytes(f: int, m: int, block_d: int) -> int:
    """Resident A [F, M] + streamed W [M, block_d] + out [F, block_d]."""
    return 4 * (f * m + m * block_d + f * block_d)


def encounter_tile_bytes(m: int, block_m: int, block_d: int) -> int:
    """Resident geometry [4, M] strip + row block [4, block_m] + streamed
    W [M, block_d] + out [block_m, block_d] + the [block_m, M] mask strip."""
    return 4 * (4 * m + 4 * block_m + m * block_d + block_m * block_d
                + block_m * m)


# ---------------------------------------------------------------------------
# measured kernel tuning (interpret path: relative block behaviour)
# ---------------------------------------------------------------------------


def _median_us(fn, reps: int) -> float:
    import jax
    jax.block_until_ready(fn())            # compile / first interpret pass
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def tune_mule_agg(f: int = 8, m: int = 64, d: int = 65536, *,
                  reps: int = 3,
                  candidates: Sequence[int] = MULE_AGG_BLOCK_D_CANDIDATES
                  ) -> Dict:
    """Measure every feasible ``block_d`` candidate; argmin wins."""
    import jax
    from repro.kernels.mule_agg.kernel import mule_agg_pallas

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    assign = jax.random.uniform(k1, (f, m))
    w = jax.random.normal(k2, (m, d))
    times: Dict[str, float] = {}
    # the kernel clamps block_d to max(128, d); dedupe on the clamped value
    # so tiny shapes still have at least one candidate
    for block_d in sorted({min(b, max(128, d)) for b in candidates}):
        if mule_agg_tile_bytes(f, m, block_d) > VMEM_BUDGET_BYTES:
            continue
        times[str(block_d)] = _median_us(
            lambda b=block_d: mule_agg_pallas(assign, w, block_d=b,
                                              interpret=True), reps)
    best = min(times, key=times.get)
    default = str(min(MULE_AGG_DEFAULT_BLOCK_D, max(128, d)))
    return {"f": f, "m": m, "d": d, "block_d": int(best),
            "candidates_us": {k: round(v, 1) for k, v in times.items()},
            "speedup_vs_default": round(times[default] / times[best], 3)
            if default in times else 1.0}


def tune_encounter_mix(m: int = 1024, d: int = 480, *, reps: int = 3,
                       radius: float = 0.1,
                       block_m_candidates: Sequence[int]
                       = ENCOUNTER_BLOCK_M_CANDIDATES,
                       block_d_candidates: Sequence[int]
                       = ENCOUNTER_BLOCK_D_CANDIDATES) -> Dict:
    """Measure every feasible (block_m, block_d) candidate; argmin wins."""
    import jax
    from repro.kernels.encounter_mix.kernel import encounter_mix_pallas

    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    pos = jax.random.uniform(ks[0], (m, 2))
    area = jax.random.randint(ks[1], (m,), 0, 2)
    active = jax.random.uniform(ks[2], (m,)) < 0.9
    w = jax.random.normal(ks[3], (m, d))
    times: Dict[str, float] = {}
    # candidates clamp exactly like the kernel does; dedupe on the clamped
    # pair so tiny shapes still have at least one candidate
    pairs = sorted({(min(bm, max(8, m)), min(bd, max(128, d)))
                    for bm in block_m_candidates
                    for bd in block_d_candidates})
    for bm, bd in pairs:
        if encounter_tile_bytes(m, bm, bd) > VMEM_BUDGET_BYTES:
            continue
        times[f"{bm}x{bd}"] = _median_us(
            lambda bm=bm, bd=bd: encounter_mix_pallas(
                pos, area, active, w, radius=radius, block_m=bm,
                block_d=bd, interpret=True)[0], reps)
    best = min(times, key=times.get)
    bm, bd = (int(x) for x in best.split("x"))
    dm, dd = ENCOUNTER_DEFAULT_BLOCKS
    default = f"{min(dm, max(8, m))}x{min(dd, max(128, d))}"
    return {"m": m, "d": d, "block_m": bm, "block_d": bd,
            "candidates_us": {k: round(v, 1) for k, v in times.items()},
            "speedup_vs_default": round(times[default] / times[best], 3)
            if default in times else 1.0}


# ---------------------------------------------------------------------------
# engine roofline: the compiled replay per (method × M × mesh)
# ---------------------------------------------------------------------------


def _engine_workload(n_mules: int, steps: int, seed: int = 0):
    """Tiny mobile linear-regression population (compiles in seconds but
    exercises every method's scan path, peer encounters included)."""
    import jax
    import jax.numpy as jnp
    from repro.core.population import PopulationConfig, init_population
    from repro.scenarios import walk_colocation

    X = jax.random.normal(jax.random.PRNGKey(50 + seed), (n_mules, 12, 5))
    Y = jax.random.normal(jax.random.PRNGKey(60 + seed), (n_mules, 12))

    def train_fn(params, batch, key):
        xb, yb = batch
        g = jax.grad(lambda p: jnp.mean((xb @ p["w"] - yb) ** 2))(params)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)

    def batch_fn(key, t):
        idx = jax.random.randint(key, (n_mules, 4), 0, X.shape[1])
        return {"fixed": None,
                "mule": (jnp.take_along_axis(X, idx[:, :, None], 1),
                         jnp.take_along_axis(Y, idx, 1))}

    pcfg = PopulationConfig(mode="mobile", n_fixed=4, n_mules=n_mules)
    pop = init_population(jax.random.PRNGKey(seed),
                          lambda k: {"w": jax.random.normal(k, (5,))}, pcfg)
    co = walk_colocation(seed, n_mules, steps)
    return pop, co, batch_fn, train_fn, pcfg


def analyze_engine_step(method: str, n_mules: int = 32, steps: int = 24,
                        mesh=None) -> Dict:
    """Compile the replay for one (method × M × mesh) cell and decompose it
    into roofline terms via the scan-aware HLO analyzer.

    Returns one row: per-device FLOPs/bytes/collective bytes of the WHOLE
    ``steps``-long replay (the scan trip count is multiplied in), the three
    terms in seconds against the per-chip peaks, per-step variants, and the
    dominant term. ``mesh=None`` is the single-host engine; a mesh routes
    through ``run_population_distributed``'s shard_map program instead.
    """
    import jax
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
    from repro.scenarios.engine import (_colocation_tensors,
                                        get_compiled_replay)

    pop, co, batch_fn, train_fn, pcfg = _engine_workload(n_mules, steps)
    fid, exch, pos, area, act = _colocation_tensors(co)
    key = jax.random.PRNGKey(7)
    if mesh is None:
        chips, mesh_name, dcfg, state = 1, "1", None, pop
    else:
        from repro.core.distributed import (DistributedConfig,
                                            to_distributed_state)
        dcfg = DistributedConfig(pop=pcfg)
        state = to_distributed_state(pop, dcfg)
        chips = mesh.size
        mesh_name = "x".join(str(s) for s in mesh.shape.values())
    fn = get_compiled_replay(state, fid, exch, pos, area, act, batch_fn,
                             None, key, train_fn, pcfg, method=method,
                             eval_every=None, eval_fn=None,
                             mesh=mesh, dcfg=dcfg)
    args = (state, fid, exch, pos, area, act, None, None, key)
    compiled = fn.lower(*args).compile()
    costs = analyze_hlo(compiled.as_text())
    t_c = costs.flops / PEAK_FLOPS
    t_m = costs.bytes / HBM_BW
    t_x = costs.coll_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    return {
        "method": method, "n_mules": n_mules, "steps": steps,
        "mesh": mesh_name, "chips": chips,
        "flops_per_device": costs.flops,
        "bytes_per_device": costs.bytes,
        "coll_bytes_per_device": costs.coll_bytes,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "t_compute_us_per_step": t_c / steps * 1e6,
        "t_memory_us_per_step": t_m / steps * 1e6,
        "t_collective_us_per_step": t_x / steps * 1e6,
        "dominant": max(terms, key=terms.get),
    }


def roofline_sweep(methods: Optional[Sequence[str]] = None,
                   mule_counts: Sequence[int] = (32, 128),
                   steps: int = 24,
                   mesh=None, meshes: Sequence = (),
                   mesh_methods: Sequence[str] = ("mlmule", "gossip"),
                   mesh_mules: int = 64) -> List[Dict]:
    """The (method × M × mesh) grid behind ``BENCH_roofline.json``.

    Single-host rows for every method at every ``mule_counts``; distributed
    rows for ``mesh_methods`` at ``mesh_mules`` on every supplied mesh
    (``mesh`` is the legacy single-mesh spelling; ``meshes`` records one
    row set per shape so ``suggest_mesh_shape`` has real alternatives to
    rank). Collective terms are zero on the single-host rows by
    construction.
    """
    from repro.core.population import METHODS_MOBILE

    if methods is None:
        methods = METHODS_MOBILE
    rows = [analyze_engine_step(m, n, steps)
            for m in methods for n in mule_counts]
    all_meshes = list(meshes) + ([mesh] if mesh is not None else [])
    for ms in all_meshes:
        rows += [analyze_engine_step(m, mesh_mules, steps, mesh=ms)
                 for m in mesh_methods]
    return rows


def _geomean(xs: Sequence[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 1.0


def run_roofline(out_path: str = DEFAULT_CACHE_PATH, *, reps: int = 3,
                 steps: int = 24, mule_counts: Sequence[int] = (32, 128),
                 methods: Optional[Sequence[str]] = None, mesh=None,
                 meshes: Sequence = (),
                 mule_agg_shapes: Sequence[Tuple[int, int, int]]
                 = ((8, 64, 4096), (8, 64, 65536)),
                 encounter_shapes: Sequence[Tuple[int, int]]
                 = ((512, 480), (2048, 480))) -> Dict:
    """Produce the full artifact: roofline grid + tuning cache + headline.

    The headline metric — ``tuned_speedup_vs_default``, the geometric mean
    over all tuned shapes of (default-block time / selected-block time) —
    is what ``bench_gate`` ratchets: it can only regress if the measured
    selection stops beating the static hand defaults.
    """
    import jax

    rows = roofline_sweep(methods=methods, mule_counts=mule_counts,
                          steps=steps, mesh=mesh, meshes=meshes)
    tuned_ma = [tune_mule_agg(f, m, d, reps=reps)
                for f, m, d in mule_agg_shapes]
    tuned_em = [tune_encounter_mix(m, d, reps=reps)
                for m, d in encounter_shapes]
    headline = _geomean([e["speedup_vs_default"]
                         for e in tuned_ma + tuned_em])
    payload = {
        "bench": "autotune.run_roofline",
        "config": {
            "backend": jax.default_backend(),
            "reps": reps, "steps": steps,
            "mule_counts": list(mule_counts),
            "mesh": (None if mesh is None
                     else "x".join(str(s) for s in mesh.shape.values())),
            "meshes": ["x".join(str(s) for s in ms.shape.values())
                       for ms in meshes],
            "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        },
        "roofline": [
            {k: (round(v, 6) if isinstance(v, float) else v)
             for k, v in r.items()} for r in rows
        ],
        "tuned": {"mule_agg": tuned_ma, "encounter_mix": tuned_em},
        "tuned_speedup_vs_default": round(headline, 3),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return payload
