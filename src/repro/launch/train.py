"""Production training launcher.

Runs a real training loop for any assigned architecture on the current
device set (CPU here; the mesh/sharding path is identical on TPU):

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 20 --batch 4 --seq 128

``--smoke`` swaps in the reduced same-family config so the loop runs on one
CPU; without it the full config is used (TPU-scale). Checkpoints + ML Mule
lineage metadata go to --ckpt-dir.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import make_lm_dataset
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adam, clip_by_global_norm, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"active~{cfg.active_param_count()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adam(cosine_schedule(args.lr, args.steps, warmup=args.steps // 10))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))

    start = 0
    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            params, meta = restore_checkpoint(ck, params)
            start = int(meta.get("step", 0))
            print(f"restored {ck} at step {start}")

    seqs, spaces = make_lm_dataset(args.seed, n_seqs=max(args.batch * 8, 64),
                                   seq_len=args.seq, vocab=cfg.vocab)
    rng = np.random.default_rng(args.seed)

    for step in range(start, args.steps):
        idx = rng.integers(0, len(seqs), size=args.batch)
        batch = {"tokens": jnp.asarray(seqs[idx])}
        if cfg.family == "vlm":
            batch["tokens"] = batch["tokens"][:, : args.seq - cfg.vision_tokens]
            batch["vision_embed"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["audio_embed"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({time.time()-t0:.2f}s/step)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params,
                            metadata={"arch": cfg.name, "loss": loss,
                                      "updated_at": step + 1})
    print("done; final loss", loss)


if __name__ == "__main__":
    main()
