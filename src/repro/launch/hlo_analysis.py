"""Scan-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers (or chunked-attention scan) model under-reports FLOPs,
bytes and collective traffic by the trip count. This module re-derives the
three roofline inputs from the compiled HLO text, walking the call graph and
multiplying while bodies by their trip counts:

- ``flops`` — 2 × result_elems × K for every dot (contracting dims parsed,
  operand shapes resolved through a module-wide symbol table), plus convs;
- ``bytes`` — per op: result bytes, plus operand bytes for dot/conv/
  collectives/copies (a deliberate approximation of HloCostAnalysis
  "bytes accessed": elementwise chains end up fused on real backends, and
  the memory roofline is dominated by parameter reads + activation writes,
  which this counts exactly);
- ``coll`` — operand bytes per collective kind (all-gather, all-reduce,
  reduce-scatter, all-to-all, collective-permute).

Trip counts come from the largest integer constant in the loop-condition
computation (exact for scan-lowered loops: ``counter < N``). Fusion ops are
leaves; their called computations are not double counted. All values are
per-device (input is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.dtypes import dtype_bytes

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))"
    r"[^\s]*\s+([\w\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "after-all", "iota"}


def _shape_bytes(txt: str) -> int:
    # unknown dtypes raise UnknownDtypeError — see repro.launch.dtypes
    total = 0
    for d, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * dtype_bytes(d)
    return total


def _shape_elems(txt: str) -> int:
    m = _SHAPE_RE.search(txt)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for x in m.group(2).split(","):
            n *= int(x)
    return n


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_const: int = 0


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if cur is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if s == "}" or s.startswith("} "):
            cur = None
            continue
        comps[cur].append(s)
    return comps


def _operand_names(rhs: str) -> List[str]:
    paren = rhs.find("(")
    if paren < 0:
        return []
    depth, end = 0, len(rhs)
    for i in range(paren, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = rhs[paren + 1:end]
    return re.findall(r"%([\w.\-]+)", inner)


def breakdown(hlo: str, top: int = 15):
    """Debug helper: top while-loops by trip-multiplied DIRECT body bytes
    (nested loops attributed to their own row)."""
    comps = _parse_computations(hlo)
    stats = _build_stats(comps)
    full = analyze_hlo(hlo)
    rows = []
    for name, st in stats.items():
        for body, cond in st.whiles:
            trip = max(stats.get(cond, CompStats()).max_const, 1)
            sub = stats.get(body, CompStats())
            rows.append((trip * sub.bytes, trip, body, sub.flops * trip))
    rows.sort(reverse=True)
    out = [(f"{b/2**30:9.2f}GiB trip={t:6d} flops={fl:.2e} {n[:60]}")
           for b, t, n, fl in rows[:top]]
    out.append(f"TOTAL bytes={full.bytes/2**40:.2f}TiB flops={full.flops:.3e}")
    return "\n".join(out)


def analyze_hlo(hlo: str, entry: Optional[str] = None) -> "HloCosts":
    comps = _parse_computations(hlo)
    stats = _build_stats(comps)

    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))

    memo: Dict[str, HloCosts] = {}

    def cost(name: str, depth: int = 0) -> HloCosts:
        if name in memo:
            return memo[name]
        if name not in stats or depth > 64:
            return HloCosts(0.0, 0.0, {})
        st = stats[name]
        fl, by = st.flops, st.bytes
        coll = dict(st.coll)
        for body, cond in st.whiles:
            trip = max(stats.get(cond, CompStats()).max_const, 1)
            sub = cost(body, depth + 1)
            fl += trip * sub.flops
            by += trip * sub.bytes
            for k, v in sub.coll.items():
                coll[k] = coll.get(k, 0.0) + trip * v
        for callee in st.calls:
            sub = cost(callee, depth + 1)
            fl += sub.flops
            by += sub.bytes
            for k, v in sub.coll.items():
                coll[k] = coll.get(k, 0.0) + v
        out = HloCosts(fl, by, coll)
        memo[name] = out
        return out

    return cost(entry)


def _build_stats(comps: Dict[str, List[str]]) -> Dict[str, CompStats]:

    # module-wide symbol table: op name -> result shape text
    sym: Dict[str, str] = {}
    for lines in comps.values():
        for s in lines:
            m = _OP_RE.match(s)
            if m:
                sym[m.group(1)] = m.group(2)

    def op_bytes_of(names: List[str]) -> int:
        return sum(_shape_bytes(sym.get(n, "")) for n in names)

    stats: Dict[str, CompStats] = {}
    for name, lines in comps.items():
        st = CompStats(coll={c: 0.0 for c in _COLLECTIVES})
        for s in lines:
            m = _OP_RE.match(s)
            if not m:
                continue
            _, result_shape, kind = m.groups()
            rhs = s.split("=", 1)[1]
            if kind == "constant":
                mc = re.search(r"s32\[\]\s*constant\((\d+)\)", rhs)
                if mc:
                    st.max_const = max(st.max_const, int(mc.group(1)))
                continue
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rhs)
                mcd = re.search(r"condition=%?([\w.\-]+)", rhs)
                if mb and mcd:
                    st.whiles.append((mb.group(1), mcd.group(1)))
                continue
            if kind in ("conditional", "call"):
                for mm in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|to_apply=%?([\w.\-]+)|"
                        r"(?:true|false)_computation=%?([\w.\-]+))", rhs):
                    for g in mm.groups():
                        if g:
                            st.calls.extend(c.strip().lstrip("%")
                                            for c in g.split(","))
                st.bytes += _shape_bytes(result_shape)
                continue
            base = kind.replace("-start", "")
            if base in _COLLECTIVES:
                ob = op_bytes_of(_operand_names(rhs))
                if ob == 0:
                    ob = _shape_bytes(result_shape)
                st.coll[base] += ob
                st.bytes += _shape_bytes(result_shape) + ob
                continue
            if kind.endswith("-done"):
                continue
            # flops
            if kind in ("dot", "dot_general"):
                res_elems = _shape_elems(result_shape)
                ops = _operand_names(rhs)
                k = 1
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if mc and ops:
                    lhs_shape = sym.get(ops[0], "")
                    mshape = _SHAPE_RE.search(lhs_shape)
                    dims = (mshape.group(2).split(",")
                            if mshape and mshape.group(2) else [])
                    for ci in mc.group(1).split(","):
                        if ci != "" and int(ci) < len(dims):
                            k *= int(dims[int(ci)])
                st.flops += 2.0 * res_elems * k
                st.bytes += _shape_bytes(result_shape) + op_bytes_of(ops[:2])
                continue
            if kind == "convolution":
                res_elems = _shape_elems(result_shape)
                ops = _operand_names(rhs)
                ker = _shape_elems(sym.get(ops[1], "")) if len(ops) > 1 else 1
                out_ch = 1
                mshape = _SHAPE_RE.search(result_shape)
                if mshape and mshape.group(2):
                    out_ch = int(mshape.group(2).split(",")[-1])
                st.flops += 2.0 * res_elems * max(ker // max(out_ch, 1), 1)
                st.bytes += _shape_bytes(result_shape) + op_bytes_of(ops[:2])
                continue
            if kind in _SKIP_BYTES:
                continue
            if kind == "dynamic-update-slice":
                # in-place window write: read+write the update only, not the
                # full aliased buffer (counting the result would charge the
                # whole KV cache per decoded token)
                ops = _operand_names(rhs)
                upd = _shape_bytes(sym.get(ops[1], "")) if len(ops) > 1 else 0
                st.bytes += 2 * upd
                continue
            st.bytes += _shape_bytes(result_shape)
            if kind in ("copy", "copy-start", "fusion", "custom-call",
                        "scatter", "gather", "sort",
                        "reduce", "transpose", "reshape", "broadcast",
                        "concatenate", "pad", "select-and-scatter"):
                # reads matter for these; dynamic-slice excluded on purpose
                # (it reads only the sliced window = its result)
                if kind in ("fusion", "custom-call"):
                    continue  # operand set too coarse; result-only
                st.bytes += op_bytes_of(_operand_names(rhs)[:3])
        stats[name] = st

    return stats




@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes: float
    coll: Dict[str, float]

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))
