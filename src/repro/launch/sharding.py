"""Sharding rules: parameter / input / cache PartitionSpecs per architecture.

Baseline policy (paper-faithful starting point; §Perf hillclimbs from here):
- tensor parallelism over the ``model`` axis: vocab, attention heads, FFN
  hidden, MoE expert axis, Mamba2 inner channels;
- batch (and the ML Mule population axis) over (``pod``, ``data``);
- small archs (xlstm-350m, whisper-base) replicate parameters and use the
  whole mesh for batch — TP would shard 4-head blocks 16 ways;
- decode KV caches: batch over ``data``; kv-heads over ``model`` when
  divisible, else head_dim; batch-1 long-context caches shard the sequence
  axis over ``data`` instead.

Optional FSDP (``fsdp=True``): additionally shards the largest parameter
dim over ``data`` — the memory-term hillclimb lever (ZeRO-3 analogue).

Every rule checks divisibility and falls back to replication, so any config
lowers on any mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import InputShape, ModelConfig

REPLICATED_ARCHS = ("xlstm", "audio")   # families too small for 16-way TP


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _dp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _shard_dim(shape, dim: int, axis, mesh: Mesh, base: Optional[list] = None):
    """P with `axis` on `dim` if divisible, else replicated there."""
    spec = base[:] if base else [None] * len(shape)
    if shape[dim] % _axis_size(mesh, axis) == 0:
        spec[dim] = axis
    return P(*spec)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(cfg: ModelConfig, params_shapes: Any, mesh: Mesh, *,
                fsdp: bool = False, replicate: bool = False) -> Any:
    """PartitionSpec pytree matching the parameter (shape) pytree.

    ``replicate=True`` forces the population-style layout (params replicated,
    the whole mesh used as data parallelism) — the right scheme for
    on-device-scale models like granite-moe-1b (§Perf pair 3)."""
    replicated = replicate or cfg.family in REPLICATED_ARCHS
    dp = _dp(mesh)

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        nd = len(shape)
        if replicated or nd == 0:
            return P()
        spec = None
        # name-based tensor-parallel rules (last dims; leading stack axes untouched)
        if name.endswith("embed"):
            spec = _shard_dim(shape, 0, "model", mesh)
        elif name.endswith("head"):
            spec = _shard_dim(shape, nd - 1, "model", mesh)
        elif "/attn/" in name or "self_attn" in name or "cross_attn" in name:
            # shard projections ONLY when whole heads land on shards —
            # otherwise GSPMD shards the contracting head_dim and all-reduces
            # attention scores every block (measured: the dominant collective
            # for 40-head qwen2.5 on a 16-way model axis)
            tp = mesh.shape["model"]
            q_ok = cfg.n_heads % tp == 0
            kv_ok = cfg.n_kv_heads % tp == 0
            if any(name.endswith(s) for s in ("wq", "bq")) and q_ok:
                spec = _shard_dim(shape, nd - 1, "model", mesh)
            elif any(name.endswith(s) for s in ("wk", "wv", "bk", "bv")) and kv_ok:
                spec = _shard_dim(shape, nd - 1, "model", mesh)
            elif name.endswith("wo") and q_ok:
                spec = _shard_dim(shape, nd - 2, "model", mesh)
            else:
                spec = P()
        elif "/moe/" in name:
            if name.endswith("router"):
                spec = P()
            else:  # [.., E, d, f] / [.., E, f, d]: expert-parallel over model
                spec = _shard_dim(shape, nd - 3, "model", mesh)
        elif "/mixer/" in name:  # Mamba2 (head-parallel TP)
            if any(name.endswith(s) for s in ("w_z", "w_x", "w_dt", "conv_x_w")):
                spec = _shard_dim(shape, nd - 1, "model", mesh)
            elif name.endswith("out_proj"):
                spec = _shard_dim(shape, nd - 2, "model", mesh)
            elif any(name.endswith(s) for s in ("A_log", "D", "dt_bias", "conv_x_b",
                                                "norm_scale")):
                spec = _shard_dim(shape, nd - 1, "model", mesh)
        elif "mlp/" in name or "/mlp" in name:
            if name.endswith("wo"):
                spec = _shard_dim(shape, nd - 2, "model", mesh)
            elif "wi_" in name:
                spec = _shard_dim(shape, nd - 1, "model", mesh)
        if spec is None:
            spec = P()
        if fsdp and nd >= 2:
            # additionally shard the largest still-unsharded dim over data
            dims = sorted(range(nd), key=lambda d: -shape[d])
            taken = list(spec) + [None] * (nd - len(spec))
            for d in dims:
                if taken[d] is None and shape[d] % _axis_size(mesh, dp) == 0 \
                        and shape[d] >= 1024:
                    taken[d] = dp
                    break
            spec = P(*taken)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                replicate: bool = False) -> Dict[str, Any]:
    """Input PartitionSpecs for train/prefill batches."""
    dp = _dp(mesh)
    small = replicate or cfg.family in REPLICATED_ARCHS
    baxis = (dp if not small else
             (("pod", "data", "model") if "pod" in mesh.axis_names
              else ("data", "model")))
    b = shape.global_batch
    if b % _axis_size(mesh, baxis) != 0:
        baxis = dp if b % _axis_size(mesh, dp) == 0 else None
    specs: Dict[str, Any] = {"tokens": P(baxis, None)}
    if cfg.family == "vlm":
        specs["vision_embed"] = P(baxis, None, None)
    if cfg.family == "audio":
        specs["audio_embed"] = P(baxis, None, None)
    return specs


def cache_specs(cfg: ModelConfig, cache_shapes: Any, batch: int, mesh: Mesh) -> Any:
    """PartitionSpecs for decode caches (pytree matching cache shapes)."""
    dp = _dp(mesh)
    dp_size = _axis_size(mesh, dp)
    small_batch = batch % dp_size != 0

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        nd = len(shape)
        spec = [None] * nd
        # locate the batch dim: first dim equal to `batch` (after any stack axis)
        try:
            bdim = next(d for d in range(nd) if shape[d] == batch)
        except StopIteration:
            return P()
        if not small_batch:
            spec[bdim] = dp
        if ("k" in name.split("/")[-1] or "v" in name.split("/")[-1]) and nd >= bdim + 4:
            # KV cache [.., B, S, KV, hd]
            sdim, kvdim, hddim = bdim + 1, bdim + 2, bdim + 3
            if shape[kvdim] % mesh.shape["model"] == 0:
                spec[kvdim] = "model"
            elif shape[hddim] % mesh.shape["model"] == 0:
                spec[hddim] = "model"
            if small_batch and shape[sdim] % dp_size == 0:
                spec[sdim] = dp
        elif "ssm" in name and nd >= bdim + 3:
            # [.., B, H, P, N]
            if shape[bdim + 1] % mesh.shape["model"] == 0:
                spec[bdim + 1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
