import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and record roofline rows.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, OOM-at-compile or unsupported collective is a
bug. The 512 placeholder host devices exist ONLY in this entrypoint (the
XLA_FLAGS line above runs before any other import, including jax).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out benchmarks/results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops_for
from repro.launch.sharding import batch_specs, cache_specs, param_specs, to_named
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import build_model
from repro.optim import adam, sgd


def opt_state_specs(opt_shapes, pspecs):
    """Optimizer-state specs mirror the parameter specs (step is replicated)."""
    def build(node):
        if isinstance(node, dict):
            return {k: (P() if k == "step" else
                        (pspecs if k in ("m", "v", "mu") else build(v)))
                    for k, v in node.items()}
        return P()

    # opt state is {"step": .., "m": params-like, "v": params-like} (or mu)
    out = {}
    for k in opt_shapes:
        out[k] = P() if k == "step" else pspecs
    return out


def skip_reason(cfg, shape, sliding_variant: bool):
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return "enc-dec ASR decoder; 500k out of family (DESIGN.md)"
        if not cfg.supports_long_context and not sliding_variant:
            return "full-attention arch; paper-faithful config skips 500k " \
                   "(run with --sliding-variant for the windowed variant)"
    return None


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool, fsdp: bool,
               optimizer: str, sliding_variant: bool, remat: bool = False,
               tp: int = 16, population: bool = False, verbose: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    reason = skip_reason(cfg, shape, sliding_variant)
    variant = ""
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": reason}
    if shape.name == "long_500k" and not cfg.supports_long_context and sliding_variant:
        cfg = dataclasses.replace(cfg, sliding_window=4096, global_layer_interval=6)
        variant = "+sliding4k"

    mesh = make_production_mesh(multi_pod=multi_pod, model_parallel=tp)
    chips = mesh.size
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    if population:
        dp_axes = dp_axes + ("model",)
    model = build_model(cfg, backend="ref", remat=remat,
                        mesh=mesh if cfg.n_experts else None, dp_axes=dp_axes,
                        moe_ep_axis=None if population else "model")
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init, key)
    pspecs = param_specs(cfg, params_shapes, mesh, fsdp=fsdp,
                         replicate=population)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            opt = adam(1e-4) if optimizer == "adam" else sgd(0.01, momentum=0.9)
            step_fn = make_train_step(model, opt)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            ospecs = opt_state_specs(opt_shapes, pspecs)
            bspecs = batch_specs(cfg, shape, mesh, replicate=population)
            batch_shapes = model.input_specs(shape)
            lowered = jax.jit(
                step_fn,
                in_shardings=(to_named(pspecs, mesh), to_named(ospecs, mesh),
                              to_named(bspecs, mesh)),
            ).lower(params_shapes, opt_shapes, batch_shapes)
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model)
            bspecs = batch_specs(cfg, shape, mesh, replicate=population)
            batch_shapes = model.input_specs(shape)
            lowered = jax.jit(
                step_fn,
                in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh)),
            ).lower(params_shapes, batch_shapes)
        else:  # decode
            step_fn = make_serve_step(model)
            specs = model.input_specs(shape)
            cspecs = cache_specs(cfg, specs["cache"], shape.global_batch, mesh)
            dp = ("pod", "data") if multi_pod else "data"
            tok_spec = P(dp, None) if shape.global_batch % (
                mesh.shape["data"] * (mesh.shape.get("pod", 1))) == 0 else P()
            lowered = jax.jit(
                step_fn,
                in_shardings=(to_named(pspecs, mesh), to_named(cspecs, mesh),
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, P())),
            ).lower(params_shapes, specs["cache"], specs["token"], specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rl = analyze(arch + variant, shape_name, "multi" if multi_pod else "single",
                 chips, compiled, model_flops_for(cfg, shape))
    row = rl.row()
    row.update(status="ok", t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1))
    if verbose:
        mem = compiled.memory_analysis()
        print(f"--- {arch}{variant} × {shape_name} × "
              f"{'multi(2x16x16)' if multi_pod else 'single(16x16)'} ---")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={rl.t_compute*1e3:.2f}ms "
              f"memory={rl.t_memory*1e3:.2f}ms "
              f"collective={rl.t_collective*1e3:.2f}ms -> {rl.dominant}")
        print(f"  collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in rl.coll_breakdown.items() if v} }")
        print(f"  useful_flops_ratio={rl.useful_flops_ratio:.3f} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", action=argparse.BooleanOptionalAction, default=True,
                    help="checkpoint each layer in train steps (default on; "
                         "--no-remat shows the unrematerialized baseline)")
    ap.add_argument("--optimizer", default="adam", choices=["adam", "sgd"])
    ap.add_argument("--tp", type=int, default=16,
                    help="logical model-parallel degree over the 256-chip pod")
    ap.add_argument("--population", action="store_true",
                    help="population-style layout: params replicated, whole "
                         "mesh as data parallelism, shard-local MoE")
    ap.add_argument("--sliding-variant", action="store_true",
                    help="run long_500k on full-attention archs with a "
                         "4k sliding-window variant")
    ap.add_argument("--out", default=None, help="append rows to this json")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    row = dryrun_one(arch, shape, multi_pod=mp, fsdp=args.fsdp,
                                     optimizer=args.optimizer, remat=args.remat,
                                     tp=args.tp, population=args.population,
                                     sliding_variant=args.sliding_variant)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                rows.append(row)
                if row.get("status") == "skip":
                    print(f"--- {arch} × {shape} × "
                          f"{'multi' if mp else 'single'}: SKIP ({row['reason']})")

    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace rows with same key
        keys = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"]) not in keys]
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(existing + rows, f, indent=1, default=str)
        print(f"wrote {len(rows)} rows -> {args.out}")

    ok = sum(1 for r in rows if r.get("status") == "ok")
    sk = sum(1 for r in rows if r.get("status") == "skip")
    print(f"\n=== dry-run: {ok} ok, {sk} skip, {failures} FAIL ===")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
