"""Step builders: the jit targets for training, prefill and decode."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import Optimizer


def make_train_step(model: Model, optimizer: Optimizer,
                    microbatches: int = 1) -> Callable:
    """Train step, optionally with gradient accumulation over microbatches
    (divides activation residency by ``microbatches``; the memory-roofline
    lever for train shapes whose temps exceed HBM — EXPERIMENTS.md §Perf)."""
    if microbatches <= 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            params, opt_state = optimizer.update(params, grads, opt_state)
            metrics = dict(metrics, loss=loss)
            return params, opt_state, metrics

        return train_step

    def train_step(params, opt_state, batch):
        def split(leaf):
            b = leaf.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return leaf.reshape((microbatches, b // microbatches) + leaf.shape[1:])

        micro = jax.tree.map(split, batch)

        def accum(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb)
            gsum = jax.tree.map(lambda a, g: a + g, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(accum, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.float32), gsum)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": lsum / microbatches}

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def make_serve_step(model: Model) -> Callable:
    def serve(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve
