"""Serving launcher: batched autoregressive decode with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --batch 4 --prompt-len 16 --gen 32

Prefill runs the full-sequence forward; decode then advances one token per
step through ``decode_step`` (greedy). On TPU the same entry point serves the
full configs under the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    b = args.batch
    max_seq = args.prompt_len + args.gen

    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, args.prompt_len),
                                0, cfg.vocab)
    cache = model.init_cache(b, max_seq, dtype=jnp.float32)
    if cfg.family == "audio":
        ae = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                     (b, cfg.encoder_seq, cfg.d_model))
        cache = model.prefill_cross_kv(params, ae, cache)

    decode = jax.jit(model.decode_step)

    # prefill by replaying prompt tokens through decode (cache-correct for
    # every family, incl. rolling windows and SSM states)
    t0 = time.time()
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompt[:, t:t + 1], jnp.int32(t))
    t_prefill = time.time() - t0

    generated = []
    t0 = time.time()
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for t in range(args.prompt_len, max_seq):
        generated.append(tok)
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(logits)
    t_gen = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {t_prefill:.2f}s | decode {t_gen:.2f}s "
          f"({b*args.gen/max(t_gen,1e-9):.1f} tok/s)")
    print("sample tokens:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
