"""Production mesh builders.

Target: TPU v5e pods. Single pod = 256 chips as a (data=16, model=16) mesh;
multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16). Functions, not
module constants — importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, model_parallel: int = 16):
    """Production mesh. ``model_parallel`` re-balances the LOGICAL data/model
    split over the same 256 chips/pod (a per-architecture tuning knob: TP
    degree must divide the attention head count or GSPMD falls back to
    score all-reduces — see EXPERIMENTS.md §Perf pair 2)."""
    data = 256 // model_parallel
    shape = (2, data, model_parallel) if multi_pod else (data, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over host devices for CI-scale distributed tests."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_mule_mesh(pod: int, data: int, *, pod_axis: str = "pod",
                   data_axis: str = "data"):
    """(pod, data) mesh for the mule-sharded scenario engine.

    The shape the roofline-driven ``suggest_mesh_shape`` emits and
    ``run_population_distributed(mesh=None)`` consumes; ``pod_axis=""``
    builds the single-axis data-only mesh a podless ``DistributedConfig``
    expects. Plain ``jax.make_mesh`` (no axis-type annotations) so it works
    on every jax the repo supports.
    """
    if not pod_axis:
        if pod != 1:
            raise ValueError(f"pod={pod} needs a pod axis name")
        return jax.make_mesh((data,), (data_axis,))
    return jax.make_mesh((pod, data), (pod_axis, data_axis))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the global batch / population dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
