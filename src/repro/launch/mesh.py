"""Production mesh builders.

Target: TPU v5e pods. Single pod = 256 chips as a (data=16, model=16) mesh;
multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16). Functions, not
module constants — importing this module never touches jax device state.

All builders are process-aware: ``jax.make_mesh`` lays the mesh out over
the *global* device list, so after ``launch.multiprocess`` bring-up the
same ``make_mule_mesh(pod, data)`` call in every process yields one
multi-host mesh (device order groups by process, so a ``P(data_axis)``
row sharding block-partitions the mule axis by process).
"""
from __future__ import annotations

import math

import jax


def _check_device_count(shape, axes) -> None:
    """Fail fast with both numbers when the shape outruns the device pool.

    Without this a mismatch surfaces deep inside ``Mesh`` construction as
    a reshape error that names neither the requested shape nor the pool.
    """
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape {dict(zip(axes, shape))} needs {need} devices but "
            f"jax.device_count()={have} "
            f"({jax.process_count()} process(es) x "
            f"{jax.local_device_count()} local device(s))")


def make_production_mesh(*, multi_pod: bool = False, model_parallel: int = 16):
    """Production mesh. ``model_parallel`` re-balances the LOGICAL data/model
    split over the same 256 chips/pod (a per-architecture tuning knob: TP
    degree must divide the attention head count or GSPMD falls back to
    score all-reduces — see EXPERIMENTS.md §Perf pair 2)."""
    data = 256 // model_parallel
    shape = (2, data, model_parallel) if multi_pod else (data, model_parallel)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _check_device_count(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over host devices for CI-scale distributed tests."""
    if pod:
        _check_device_count((pod, data, model), ("pod", "data", "model"))
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    _check_device_count((data, model), ("data", "model"))
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_mule_mesh(pod: int, data: int, *, pod_axis: str = "pod",
                   data_axis: str = "data"):
    """(pod, data) mesh for the mule-sharded scenario engine.

    The shape the roofline-driven ``suggest_mesh_shape`` emits and
    ``run_population_distributed(mesh=None)`` consumes; ``pod_axis=""``
    builds the single-axis data-only mesh a podless ``DistributedConfig``
    expects. Plain ``jax.make_mesh`` (no axis-type annotations) so it works
    on every jax the repo supports. Under multi-process bring-up the mesh
    spans every process's devices — pass the *global* shard counts.
    """
    if not pod_axis:
        if pod != 1:
            raise ValueError(f"pod={pod} needs a pod axis name")
        _check_device_count((data,), (data_axis,))
        return jax.make_mesh((data,), (data_axis,))
    _check_device_count((pod, data), (pod_axis, data_axis))
    return jax.make_mesh((pod, data), (pod_axis, data_axis))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the global batch / population dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
