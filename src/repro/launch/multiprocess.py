"""Multi-process bring-up for the mule mesh (``jax.distributed``).

Three small layers, in the order a run uses them:

1. **Spawn** — ``spawn_local_cluster`` launches N copies of an argv as a
   local CPU cluster (one coordinator port, ``N`` processes with
   ``devices_per_process`` forced host devices each).  The environment
   each child needs is built by ``local_cluster_env`` and must be in
   place *before the child imports jax* — which is why the cluster is
   spawned as subprocesses rather than forked workers.
2. **Init** — inside each process, ``initialize_from_env`` (or the
   explicit ``initialize_process``) selects the ``gloo`` CPU
   collectives backend and calls ``jax.distributed.initialize``.  After
   this, ``jax.devices()`` spans the whole cluster and every mesh built
   by ``launch.mesh.make_mule_mesh`` is a multi-host mesh.
3. **Place** — ``put_global`` / ``put_global_tree`` commit host arrays
   to a (possibly multi-host) ``NamedSharding``.  Leaves sharded on
   their leading axis go through
   ``jax.make_array_from_process_local_data`` so each process hands the
   runtime only its own row block (its shard of the generator columns
   and mule state); replicated leaves go through
   ``jax.make_array_from_callback``.

Everything degrades to a no-op single-process path: ``num_processes=1``
skips ``jax.distributed`` entirely and ``put_global`` on a
single-process mesh is an ordinary ``device_put``-equivalent, so the
engines call these helpers unconditionally.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

ENV_COORDINATOR = "REPRO_MP_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_MP_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_MP_PROCESS_ID"

_initialized = False


def pick_free_port(host: str = "127.0.0.1") -> int:
    """Bind-then-release a port for the coordinator of a local cluster."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def local_cluster_env(process_id: int, num_processes: int, coordinator: str,
                      devices_per_process: int,
                      base_env: Optional[Dict[str, str]] = None
                      ) -> Dict[str, str]:
    """Environment for one process of a local CPU cluster.

    Must be installed before the child imports jax: the forced host
    device count is read at backend bring-up and ``JAX_PLATFORMS=cpu``
    keeps the child off any accelerator the parent may see.  The
    coordinator/process-id triple rides on ``REPRO_MP_*`` variables that
    ``initialize_from_env`` consumes inside the child.
    """
    env = dict(os.environ if base_env is None else base_env)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{devices_per_process}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(process_id)
    return env


def initialize_process(coordinator_address: str, num_processes: int,
                       process_id: int) -> None:
    """``jax.distributed`` bring-up over the gloo CPU collectives backend.

    Call before any jax computation (the distributed service must come
    up before the backend initializes).  Idempotent; a 1-process
    "cluster" skips ``jax.distributed`` entirely.
    """
    global _initialized
    if _initialized or num_processes <= 1:
        return
    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    _initialized = True


def initialize_from_env(env=None) -> bool:
    """Init from ``REPRO_MP_*`` variables; returns True when they were set.

    The hook every spawned entry point calls first thing: parents launch
    children via ``spawn_local_cluster``/``local_cluster_env`` and the
    child picks the coordinator triple back up here.
    """
    env = os.environ if env is None else env
    coord = env.get(ENV_COORDINATOR)
    if not coord:
        return False
    initialize_process(coord, int(env[ENV_NUM_PROCESSES]),
                       int(env[ENV_PROCESS_ID]))
    return True


def spawn_local_cluster(argv: Sequence[str], num_processes: int,
                        devices_per_process: int = 1, *,
                        coordinator: Optional[str] = None,
                        base_env: Optional[Dict[str, str]] = None,
                        capture: bool = True, timeout: Optional[float] = None,
                        ) -> List[subprocess.CompletedProcess]:
    """Run ``argv`` as an N-process local CPU cluster; one result per rank.

    All ranks launch concurrently (they must — ``jax.distributed``
    blocks every process until the whole cluster has dialed the
    coordinator).  stdout/stderr are captured per rank when ``capture``;
    the caller decides which rank's output to surface.
    """
    coord = coordinator or f"127.0.0.1:{pick_free_port()}"
    pipes = dict(stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                 text=True) if capture else {}
    procs = [subprocess.Popen(
        list(argv),
        env=local_cluster_env(pid, num_processes, coord,
                              devices_per_process, base_env),
        **pipes) for pid in range(num_processes)]
    results = []
    try:
        for pid, p in enumerate(procs):
            out, _ = p.communicate(timeout=timeout)
            results.append(subprocess.CompletedProcess(
                list(argv), p.returncode, stdout=out, stderr=None))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return results


# ---------------------------------------------------------------------------
# per-process data placement
# ---------------------------------------------------------------------------


def put_global(x, mesh, spec):
    """Commit ``x`` to ``NamedSharding(mesh, spec)``, multi-host safe.

    Arrays that are already global (not fully addressable — i.e. already
    placed on a multi-host mesh) pass through untouched.  Leaves whose
    leading axis is sharded hand jax only this process's contiguous row
    block via ``jax.make_array_from_process_local_data``; everything
    else (replicated leaves, scalars, keys) goes through
    ``jax.make_array_from_callback``, which only materializes
    addressable shards.
    """
    import jax
    from jax.sharding import NamedSharding

    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        return x
    sharding = NamedSharding(mesh, spec)
    arr = np.asarray(x)
    row_sharded = (arr.ndim > 0 and len(spec) > 0 and spec[0] is not None)
    if row_sharded:
        idx_map = sharding.addressable_devices_indices_map(arr.shape)
        starts = [idx[0].start or 0 for idx in idx_map.values()]
        stops = [arr.shape[0] if idx[0].stop is None else idx[0].stop
                 for idx in idx_map.values()]
        local = np.ascontiguousarray(arr[min(starts):max(stops)])
        return jax.make_array_from_process_local_data(sharding, local,
                                                      arr.shape)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def put_global_tree(tree, mesh, specs):
    """``put_global`` over a pytree with a matching specs tree."""
    import jax
    return jax.tree.map(lambda x, s: put_global(x, mesh, s), tree, specs)


def gather_global(x) -> np.ndarray:
    """Host numpy copy of any array, multi-host safe.

    Replicated leaves read this process's replica (no traffic); leaves
    sharded across processes allgather their row blocks
    (``multihost_utils.process_allgather``). The hook experiment drivers
    use to pull a distributed run's final state back for host-side
    metrics — on single-process arrays it is exactly ``np.asarray``.
    """
    import jax
    if not (isinstance(x, jax.Array) and not x.is_fully_addressable):
        return np.asarray(x)
    shard = x.addressable_shards[0]
    if shard.data.shape == x.shape:
        return np.asarray(shard.data)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


def host_replicated(x) -> np.ndarray:
    """Read a replicated global array back on the host, multi-host safe.

    ``np.asarray`` refuses arrays whose devices span processes; for a
    replicated value every process's first addressable shard *is* the
    full value, so read that.  Sharded arrays don't belong here —
    gather them (e.g. ``multihost_utils.process_allgather``) instead.
    """
    import jax
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        shard = x.addressable_shards[0]
        if shard.data.shape != x.shape:
            raise ValueError(
                f"host_replicated needs a replicated array; got shard shape "
                f"{shard.data.shape} for global shape {x.shape}")
        return np.asarray(shard.data)
    return np.asarray(x)
