"""Render dry-run/roofline JSON into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report \
      --single benchmarks/results/dryrun_single.json \
      --multi benchmarks/results/dryrun_multi.json
"""
from __future__ import annotations

import argparse
import json


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def roofline_table(rows):
    out = ["| arch | shape | dominant | t_compute | t_memory | t_collective | "
           "mem/chip | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** | "
            f"{_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} | "
            f"{_fmt_s(r['t_collective_s'])} | "
            f"{r['peak_memory_gb']:.1f}GB | {r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def skip_table(rows):
    out = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['reason']} |")
    return "\n".join(out)


def compile_proof_table(rows):
    out = ["| arch | shape | mesh | status | lower | compile |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                       f"{r.get('t_lower_s','-')}s | {r.get('t_compile_s','-')}s |")
        elif r.get("status") == "FAIL":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"**FAIL** {r.get('error','')} | - | - |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="benchmarks/results/dryrun_single.json")
    ap.add_argument("--multi", default=None)
    ap.add_argument("--mode", default="roofline",
                    choices=["roofline", "skips", "compile"])
    args = ap.parse_args()
    with open(args.single) as f:
        rows = json.load(f)
    if args.multi:
        with open(args.multi) as f:
            rows += json.load(f)
    if args.mode == "roofline":
        print(roofline_table(rows))
    elif args.mode == "skips":
        print(skip_table(rows))
    else:
        print(compile_proof_table(rows))


if __name__ == "__main__":
    main()
