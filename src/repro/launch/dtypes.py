"""One dtype-width table for every HLO text parser.

``roofline.py`` and ``hlo_analysis.py`` each used to carry a private
``_DTYPE_BYTES`` dict with a silent ``.get(dtype, 4)`` fallback — an HLO
module using a dtype neither table knew (a new fp8 variant, a packed int)
would be costed as f32 without a whisper, skewing every roofline term
derived from it. This module is now the single source of truth, and unknown
dtypes are LOUD: ``dtype_bytes`` raises :class:`UnknownDtypeError` naming
the offending dtype, or — when the caller passes a ``collect`` set —
records it there and falls back to 4 bytes so a full-module sweep can
report every unknown at once instead of dying on the first.
"""
from __future__ import annotations

from typing import Optional, Set

# Width in bytes of every HLO element type the analyzers understand. The
# sub-byte types (s4/u4, pred packing) are charged one byte — HLO buffers
# round them up to byte granularity per element in the dumps we parse.
DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "token": 0,   # sequencing tokens carry no data
}


class UnknownDtypeError(ValueError):
    """An HLO shape names a dtype missing from :data:`DTYPE_BYTES`."""

    def __init__(self, dtype: str):
        self.dtype = dtype
        super().__init__(
            f"unknown HLO dtype {dtype!r}: add it to "
            f"repro.launch.dtypes.DTYPE_BYTES (silent f32 fallbacks skew "
            f"roofline terms)")


def dtype_bytes(dtype: str, collect: Optional[Set[str]] = None) -> int:
    """Bytes per element of an HLO dtype name.

    Raises :class:`UnknownDtypeError` for names not in the table; with a
    ``collect`` set, unknown names are recorded there and costed as 4 bytes
    so the caller can finish the sweep and report them all.
    """
    width = DTYPE_BYTES.get(dtype)
    if width is None:
        if collect is None:
            raise UnknownDtypeError(dtype)
        collect.add(dtype)
        return 4
    return width
