"""Roofline term extraction from compiled dry-run artifacts.

Three terms, in seconds, per (arch × shape × mesh):

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s per ICI link)

``cost_analysis`` reports the per-device partitioned program, so FLOPs/bytes
are multiplied back by chip count before normalizing (i.e. the terms equal
the per-device values divided by per-chip peaks). collective_bytes is parsed
from the compiled HLO: the summed operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (per device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.dtypes import dtype_bytes

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

# attribute blobs whose quoted strings can contain shape-shaped text
_ATTR_NOISE_RE = re.compile(r"(?:metadata=\{[^}]*\}|backend_config=\S+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * dtype_bytes(dtype)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of collective ops in an HLO module dump.

    Unknown dtypes raise :class:`repro.launch.dtypes.UnknownDtypeError`
    rather than being silently costed as f32.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = _ATTR_NOISE_RE.sub("", line.strip())
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if f"{op}-done(" in rhs:
            continue  # avoid double counting start/done pairs
        # operand shapes appear inside the call parens; result shape before op name
        paren = rhs.find("(")
        operand_part = rhs[paren:]
        shapes = _SHAPE_RE.findall(operand_part)
        if shapes:
            out[op] += sum(_shape_bytes(d, dims) for d, dims in shapes)
        else:  # fall back to result shape
            shapes = _SHAPE_RE.findall(rhs[:paren])
            out[op] += sum(_shape_bytes(d, dims) for d, dims in shapes)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    peak_memory_bytes: Optional[float]
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "peak_memory_gb": (self.peak_memory_bytes / 2**30
                               if self.peak_memory_bytes else None),
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            compiled, model_flops: float) -> Roofline:
    """Derive roofline terms from a compiled artifact.

    FLOPs/bytes/collectives come from the scan-aware HLO analyzer
    (``hlo_analysis``) — XLA's cost_analysis counts while bodies once, which
    under-reports scan-over-layers models by the layer count.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=costs.flops, bytes_per_device=costs.bytes,
        coll_bytes_per_device=costs.coll_bytes,
        coll_breakdown={k: int(v) for k, v in costs.coll.items() if v},
        peak_memory_bytes=peak, model_flops=model_flops)


def model_flops_for(cfg, shape) -> float:
    """6·N·D train / 2·N·D prefill / 2·N·B decode (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch
