"""Model zoo.

- ``transformer.py`` — unified decoder LM covering dense / moe / ssm / hybrid /
  vlm families plus the xLSTM stack; ``build_model(config)`` returns a
  ``Model`` with init / forward / decode_step / init_cache.
- ``whisper.py`` — encoder-decoder (audio family).
- ``cnn.py`` — the paper's CNN and LSTM-CNN used by the ML Mule simulations.
"""
from repro.models.api import Model, build_model  # noqa: F401
