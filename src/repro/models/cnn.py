"""The paper's task models.

- ``CNN`` (Sec 4.2.1): two conv blocks (3x3 conv, batch norm, ReLU, 2x2 max
  pool) + a two-layer FC classifier — CIFAR-100 super-class task.
- ``LSTM-CNN`` (Sec 4.3.1, Xia et al. 2020): two strided 1-D conv blocks over
  the IMU window followed by an LSTM and a dense classifier — HAR task.

Batch norm uses in-batch statistics (no running stats); in federated
simulations the learned scale/bias are part of the exchanged model, which is
the common convention in FL research on small CNNs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.mule_cnn import CNNConfig
from repro.configs.mule_lstm_cnn import LSTMCNNConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# CNN (image classification)
# ---------------------------------------------------------------------------


def init_cnn(key, cfg: CNNConfig):
    f1, f2 = cfg.conv_features
    ks = jax.random.split(key, 4)
    flat = (cfg.image_size // 4) * (cfg.image_size // 4) * f2
    return {
        "conv1": dense_init(ks[0], (3, 3, cfg.channels, f1), scale=0.1),
        "bn1": {"scale": jnp.ones((f1,)), "bias": jnp.zeros((f1,))},
        "conv2": dense_init(ks[1], (3, 3, f1, f2), scale=0.1),
        "bn2": {"scale": jnp.ones((f2,)), "bias": jnp.zeros((f2,))},
        "fc1": dense_init(ks[2], (flat, cfg.hidden), scale=0.05),
        "fc1_b": jnp.zeros((cfg.hidden,)),
        "fc2": dense_init(ks[3], (cfg.hidden, cfg.n_classes), scale=0.05),
        "fc2_b": jnp.zeros((cfg.n_classes,)),
    }


def _conv2d(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def cnn_forward(params, images):
    """images: [B, H, W, C] -> logits [B, n_classes]."""
    x = _pool(jax.nn.relu(_bn(_conv2d(images, params["conv1"]), params["bn1"])))
    x = _pool(jax.nn.relu(_bn(_conv2d(x, params["conv2"]), params["bn2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["fc1_b"])
    return x @ params["fc2"] + params["fc2_b"]


# ---------------------------------------------------------------------------
# LSTM-CNN (IMU HAR)
# ---------------------------------------------------------------------------


def init_lstm_cnn(key, cfg: LSTMCNNConfig):
    f1, f2 = cfg.conv_features
    h = cfg.lstm_hidden
    ks = jax.random.split(key, 6)
    return {
        "conv1": dense_init(ks[0], (5, cfg.channels, f1), scale=0.1),
        "conv1_b": jnp.zeros((f1,)),
        "conv2": dense_init(ks[1], (5, f1, f2), scale=0.1),
        "conv2_b": jnp.zeros((f2,)),
        "lstm_wx": dense_init(ks[2], (f2, 4 * h), scale=0.08),
        "lstm_wh": dense_init(ks[3], (h, 4 * h), scale=0.08),
        "lstm_b": jnp.zeros((4 * h,)),
        "fc": dense_init(ks[4], (h, cfg.n_classes), scale=0.05),
        "fc_b": jnp.zeros((cfg.n_classes,)),
    }


def _conv1d(x, w, b, stride):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def lstm_cnn_forward(params, x):
    """x: [B, T, C] IMU window -> logits [B, n_classes]."""
    h1 = jax.nn.relu(_conv1d(x, params["conv1"], params["conv1_b"], 2))
    h2 = jax.nn.relu(_conv1d(h1, params["conv2"], params["conv2_b"], 2))
    b, t, f = h2.shape
    hidden = params["lstm_wh"].shape[0]

    def lstm_step(carry, xt):
        h, c = carry
        gates = xt @ params["lstm_wx"] + h @ params["lstm_wh"] + params["lstm_b"]
        i, f_, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f_ + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((b, hidden))
    (h, _), _ = jax.lax.scan(lstm_step, (h0, h0), jnp.moveaxis(h2, 1, 0))
    return h @ params["fc"] + params["fc_b"]


# ---------------------------------------------------------------------------
# shared loss / metric helpers
# ---------------------------------------------------------------------------


def xent_loss(logits, labels):
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
