"""Unified model API: every assigned architecture becomes a ``Model`` with
``init / loss / forward / init_cache / decode_step / input_specs``.

A config is compiled into a **stage program**: consecutive layers of the same
kind (same attention window, same mixer) are grouped and executed with a
single ``lax.scan`` over stacked parameters — this keeps HLO size and compile
time bounded at 94 layers while still allowing heterogeneous stacks
(gemma3 5:1 local:global, zamba2 mamba+shared-attn, xLSTM mLSTM/sLSTM pairs).

Stage kinds:
- ``attn``        — GQA attention + gated MLP (window=None or int)
- ``moe``         — GQA attention + mixture-of-experts FFN
- ``mamba``       — Mamba2/SSD mixer
- ``shared_attn`` — zamba2's shared-weight attention block (params stored once)
- ``xlstm_pair``  — (mLSTM block, sLSTM block) pair
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import InputShape, ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import apply_mlp, apply_norm, dense_init, init_mlp, init_norm


@dataclasses.dataclass(frozen=True)
class Stage:
    kind: str            # attn | moe | mamba | shared_attn | xlstm_pair
    count: int           # number of layers folded into this stage
    window: Optional[int] = None


# ---------------------------------------------------------------------------
# program construction
# ---------------------------------------------------------------------------


def build_program(cfg: ModelConfig) -> List[Stage]:
    if cfg.family == "xlstm":
        assert cfg.n_layers % 2 == 0, "xlstm program scans (mLSTM, sLSTM) pairs"
        return [Stage("xlstm_pair", cfg.n_layers // 2)]

    kinds: List[Tuple[str, Optional[int]]] = []
    for layer in range(cfg.n_layers):
        if cfg.family in ("ssm", "hybrid"):
            if cfg.attn_layer_interval and (layer + 1) % cfg.attn_layer_interval == 0:
                kinds.append(("shared_attn", None))
            else:
                kinds.append(("mamba", None))
        else:
            window = cfg.sliding_window
            if window is not None and cfg.global_layer_interval:
                if (layer + 1) % cfg.global_layer_interval == 0:
                    window = None  # global layer
            kind = "moe" if cfg.n_experts else "attn"
            kinds.append((kind, window))

    stages: List[Stage] = []
    for kind, window in kinds:
        if stages and stages[-1].kind == kind and stages[-1].window == window \
                and kind != "shared_attn":
            stages[-1] = Stage(kind, stages[-1].count + 1, window)
        else:
            stages.append(Stage(kind, 1, window))
    return stages


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, kind: str):
    if kind in ("attn", "moe", "shared_attn"):
        k1, k2 = jax.random.split(key)
        p = {"norm1": init_norm(cfg.norm, cfg.d_model),
             "attn": attn_lib.init_attention(k1, cfg),
             "norm2": init_norm(cfg.norm, cfg.d_model)}
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
        return p
    if kind == "mamba":
        return {"norm": init_norm(cfg.norm, cfg.d_model),
                "mixer": mamba_lib.init_mamba2(key, cfg)}
    if kind == "xlstm_pair":
        k1, k2 = jax.random.split(key)
        return {"mlstm": xlstm_lib.init_mlstm(k1, cfg),
                "slstm": xlstm_lib.init_slstm(k2, cfg)}
    raise ValueError(kind)


def _apply_layer(params, x, positions, cfg: ModelConfig, kind: str,
                 window: Optional[int], shared_params=None, backend: str = "ref",
                 mesh=None, dp_axes=("data",), head_axis=None, seq_axis=None,
                 moe_ep_axis="model"):
    """Full-sequence forward for one layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe", "shared_attn"):
        p = shared_params if kind == "shared_attn" else params
        h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        x = x + attn_lib.attn_forward(p["attn"], h, positions, cfg,
                                      window=window, backend=backend,
                                      head_axis=head_axis, seq_axis=seq_axis)
        h = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if kind == "moe":
            out, aux = moe_lib.apply_moe(p["moe"], h, cfg, mesh=mesh,
                                         dp_axes=dp_axes, ep_axis=moe_ep_axis)
            x = x + out
        else:
            x = x + apply_mlp(p["mlp"], h, cfg.act, jnp.dtype(cfg.dtype))
        return x, aux
    if kind == "mamba":
        h = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
        return x + mamba_lib.mamba2_forward(params["mixer"], h, cfg, backend=backend), aux
    if kind == "xlstm_pair":
        x = xlstm_lib.mlstm_forward(params["mlstm"], x, cfg)
        x = xlstm_lib.slstm_forward(params["slstm"], x, cfg, backend=backend)
        return x, aux
    raise ValueError(kind)


def _decode_layer(params, x, cache, pos, cfg: ModelConfig, kind: str,
                  window: Optional[int], shared_params=None, mesh=None,
                  dp_axes=("data",)):
    """Single-token decode for one layer. Returns (x, new_cache)."""
    if kind in ("attn", "moe", "shared_attn"):
        p = shared_params if kind == "shared_attn" else params
        h = apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
        out, cache = attn_lib.attn_decode(p["attn"], h, cache, pos, cfg, window=window)
        x = x + out
        h = apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if kind == "moe":
            out, _ = moe_lib.apply_moe(p["moe"], h, cfg, mesh=mesh,
                                       dp_axes=dp_axes)
            x = x + out
        else:
            x = x + apply_mlp(p["mlp"], h, cfg.act, jnp.dtype(cfg.dtype))
        return x, cache
    if kind == "mamba":
        h = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
        out, cache = mamba_lib.mamba2_decode(params["mixer"], h, cache, cfg)
        return x + out, cache
    if kind == "xlstm_pair":
        x, mc = xlstm_lib.mlstm_decode(params["mlstm"], x, cache["mlstm"], cfg)
        x, sc = xlstm_lib.slstm_decode(params["slstm"], x, cache["slstm"], cfg)
        return x, {"mlstm": mc, "slstm": sc}
    raise ValueError(kind)


def _init_stage_cache(cfg: ModelConfig, stage: Stage, batch: int, max_seq: int,
                      dtype=jnp.bfloat16):
    def one():
        if stage.kind in ("attn", "moe", "shared_attn"):
            return attn_lib.init_kv_cache(cfg, batch, max_seq, window=stage.window,
                                          dtype=dtype)
        if stage.kind == "mamba":
            return mamba_lib.init_mamba2_cache(cfg, batch)
        if stage.kind == "xlstm_pair":
            return {"mlstm": xlstm_lib.init_mlstm_cache(cfg, batch),
                    "slstm": xlstm_lib.init_slstm_cache(cfg, batch)}
        raise ValueError(stage.kind)

    c = one()
    if stage.count > 1:
        c = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (stage.count,) + l.shape), c)
    return c


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    program: List[Stage]
    backend: str = "ref"          # attention/ssm kernel backend
    remat: bool = False           # checkpoint each layer in the train path
    unroll: bool = False          # python-loop layers instead of lax.scan
                                  # (dry-run cost analysis counts scan bodies
                                  # once; unrolling makes HLO costs exact)
    mesh: Any = None              # Mesh for expert-parallel shard_map (MoE)
    dp_axes: tuple = ("data",)    # mesh axes carrying the batch
    remat_policy: str = "full"    # full | dots (save matmul outputs so the
                                  # backward recompute skips TP all-reduces)
    head_axis: Any = None         # shard attention heads over this mesh axis
                                  # via activation constraints (GSPMD pads
                                  # non-divisible head counts)
    seq_axis: Any = None          # context parallelism: shard attention over
                                  # the sequence dim instead (KV all-gather)
    moe_ep_axis: Any = "model"    # MoE expert-parallel axis; None = pure-DP
                                  # replicated-expert shard_map

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.program) + 4)
        params: Dict[str, Any] = {
            "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model)),
            "final_norm": init_norm(cfg.norm, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab))
        if any(s.kind == "shared_attn" for s in self.program):
            params["shared_attn"] = _init_layer(keys[2], cfg, "shared_attn")

        stage_params = []
        for i, stage in enumerate(self.program):
            sk = jax.random.split(jax.random.fold_in(key, 1000 + i), stage.count)
            layers = [_init_layer(k, cfg, stage.kind) for k in sk]
            if stage.kind == "shared_attn":
                stage_params.append({})  # weights live in params["shared_attn"]
            elif stage.count > 1:
                stage_params.append(jax.tree.map(lambda *ls: jnp.stack(ls), *layers))
            else:
                stage_params.append(layers[0])
        params["stages"] = stage_params
        return params

    # -- embedding helpers ----------------------------------------------------
    def _embed(self, params, tokens, extra: Dict[str, Any]):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            vis = extra["vision_embed"].astype(x.dtype)       # [B, vt, D]
            x = jnp.concatenate([vis, x], axis=1)
        return x

    def _unembed(self, params, x):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return (x.astype(jnp.float32) @ w.astype(jnp.float32))

    def _positions(self, batch_size: int, seq: int):
        cfg = self.cfg
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch_size, seq))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos[None], (3, batch_size, seq))
        return pos

    # -- full-sequence forward ------------------------------------------------
    def forward(self, params, batch: Dict[str, Any]):
        """Returns (logits [B,S,V], aux_loss). batch: tokens [+ vision_embed]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch)
        b, s, _ = x.shape
        positions = batch.get("positions")
        if positions is None:
            positions = self._positions(b, s)
        aux_total = jnp.zeros((), jnp.float32)
        shared = params.get("shared_attn")

        for stage, sp in zip(self.program, params["stages"]):
            body = functools.partial(_apply_layer, cfg=cfg, kind=stage.kind,
                                     window=stage.window, shared_params=shared,
                                     backend=self.backend, positions=positions,
                                     mesh=self.mesh, dp_axes=self.dp_axes,
                                     head_axis=self.head_axis,
                                     seq_axis=self.seq_axis,
                                     moe_ep_axis=self.moe_ep_axis)
            if self.remat:
                policy = None
                if self.remat_policy == "dots":
                    policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body = jax.checkpoint(body, policy=policy)
            if stage.count > 1 and not self.unroll:
                def scan_fn(carry, layer_params, _body=body):
                    x, aux = carry
                    x, a = _body(layer_params, x)
                    return (x, aux + a), None
                (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total), sp)
            elif stage.count > 1:
                for li in range(stage.count):
                    lp = jax.tree.map(lambda l, _li=li: l[_li], sp)
                    x, a = body(lp, x)
                    aux_total = aux_total + a
            else:
                x, a = body(sp, x)
                aux_total = aux_total + a
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return self._unembed(params, x), aux_total

    # -- loss -----------------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any]):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        if cfg.family == "vlm":   # drop the vision prefix from the loss
            logits = logits[:, cfg.vision_tokens:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll) + 0.01 * aux
        return loss, {"nll": jnp.mean(nll), "aux": aux}

    # -- decode ----------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return [
            _init_stage_cache(self.cfg, s, batch, max_seq, dtype) for s in self.program
        ]

    def decode_step(self, params, cache, token, pos):
        """token: [B,1] int32; pos: scalar int32. Returns (logits [B,V], cache)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], token, axis=0).astype(jnp.dtype(cfg.dtype))
        shared = params.get("shared_attn")
        new_cache = []
        for stage, sp, sc in zip(self.program, params["stages"], cache):
            if stage.count > 1 and not self.unroll:
                def scan_fn(x, inp, _stage=stage):
                    layer_params, layer_cache = inp
                    x, nc = _decode_layer(layer_params, x, layer_cache, pos, cfg,
                                          _stage.kind, _stage.window, shared,
                                          self.mesh, self.dp_axes)
                    return x, nc
                x, nc = jax.lax.scan(scan_fn, x, (sp, sc))
            elif stage.count > 1:
                ncs = []
                for li in range(stage.count):
                    lp = jax.tree.map(lambda l, _li=li: l[_li], sp)
                    lc = jax.tree.map(lambda l, _li=li: l[_li], sc)
                    x, nc1 = _decode_layer(lp, x, lc, pos, cfg, stage.kind,
                                           stage.window, shared, self.mesh,
                                           self.dp_axes)
                    ncs.append(nc1)
                nc = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)
            else:
                x, nc = _decode_layer(sp, x, sc, pos, cfg, stage.kind, stage.window,
                                      shared, self.mesh, self.dp_axes)
            new_cache.append(nc)
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        return self._unembed(params, x)[:, 0], new_cache

    # -- dry-run input specs ----------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        if shape.kind in ("train", "prefill"):
            s = shape.seq_len
            specs: Dict[str, Any] = {}
            if cfg.family == "vlm":
                specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.vision_tokens), jnp.int32)
                specs["vision_embed"] = jax.ShapeDtypeStruct(
                    (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            return specs
        # decode: one token + cache
        return {
            "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": jax.eval_shape(
                lambda: self.init_cache(b, shape.seq_len)),
        }


def build_model(cfg: ModelConfig, *, backend: str = "ref", remat: bool = False,
                unroll: bool = False, mesh: Any = None,
                dp_axes: tuple = ("data",), remat_policy: str = "full",
                head_axis: Any = None, seq_axis: Any = None,
                moe_ep_axis: Any = "model") -> Model:
    kw = dict(cfg=cfg, program=build_program(cfg), backend=backend, remat=remat,
              unroll=unroll, mesh=mesh, dp_axes=dp_axes,
              remat_policy=remat_policy, head_axis=head_axis,
              seq_axis=seq_axis, moe_ep_axis=moe_ep_axis)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperModel
        return WhisperModel(**kw)
    return Model(**kw)
