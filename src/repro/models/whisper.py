"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings [B, encoder_seq, D]
(what the conv frontend would emit). The transformer backbone — bidirectional
encoder, causal decoder with cross-attention — is implemented fully.

Deviation noted in DESIGN.md: the decoder uses sinusoidal (not learned)
positional embeddings so the module stays shape-agnostic for the assigned
decode shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import InputShape, ModelConfig
from repro.models import attention as attn_lib
from repro.models.api import Model
from repro.models.layers import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 sinusoidal_positions)


def init_encoder(key, cfg: ModelConfig):
    layers = []
    for i in range(cfg.encoder_layers):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        layers.append({
            "norm1": init_norm(cfg.norm, cfg.d_model),
            "attn": attn_lib.init_attention(k1, cfg),
            "norm2": init_norm(cfg.norm, cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
        })
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *layers)
    return {"layers": stacked, "final_norm": init_norm(cfg.norm, cfg.d_model)}


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.norm, cfg.d_model),
        "self_attn": attn_lib.init_attention(k1, cfg),
        "norm_x": init_norm(cfg.norm, cfg.d_model),
        "cross_attn": attn_lib.init_attention(k2, cfg),
        "norm2": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


@dataclasses.dataclass
class WhisperModel(Model):
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_enc, k_dec, k_emb = jax.random.split(key, 3)
        from repro.models.layers import dense_init
        dec_layers = [_init_dec_layer(jax.random.fold_in(k_dec, i), cfg)
                      for i in range(cfg.n_layers)]
        return {
            "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model)),
            "encoder": init_encoder(k_enc, cfg),
            "decoder": jax.tree.map(lambda *ls: jnp.stack(ls), *dec_layers),
            "final_norm": init_norm(cfg.norm, cfg.d_model),
        }

    # -- encoder ---------------------------------------------------------------
    def encode(self, params, audio_embed):
        cfg = self.cfg
        b, se, _ = audio_embed.shape
        x = audio_embed.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoidal_positions(se, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))

        def enc_layer(x, lp):
            h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
            x = x + attn_lib.attn_forward(lp["attn"], h, positions, cfg,
                                          causal=False, rope=False,
                                          backend=self.backend)
            h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
            return x + apply_mlp(lp["mlp"], h, cfg.act, jnp.dtype(cfg.dtype)), None

        x = self._run_layers(enc_layer, x, params["encoder"]["layers"],
                             cfg.encoder_layers)
        return apply_norm(params["encoder"]["final_norm"], x, cfg.norm, cfg.norm_eps)

    def _run_layers(self, body, x, stacked, count):
        if not self.unroll:
            x, _ = jax.lax.scan(body, x, stacked)
            return x
        for li in range(count):
            lp = jax.tree.map(lambda l, _li=li: l[_li], stacked)
            x, _ = body(x, lp)
        return x

    # -- decoder full-sequence ----------------------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_embed"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def dec_layer(x, lp):
            h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
            x = x + attn_lib.attn_forward(lp["self_attn"], h, positions, cfg,
                                          causal=True, rope=False,
                                          backend=self.backend)
            h = apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
            ck, cv = attn_lib.cross_kv(lp["cross_attn"], enc_out, cfg)
            x = x + attn_lib.cross_attn_forward(lp["cross_attn"], h, ck, cv, cfg)
            h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
            return x + apply_mlp(lp["mlp"], h, cfg.act, jnp.dtype(cfg.dtype)), None

        x = self._run_layers(dec_layer, x, params["decoder"], cfg.n_layers)
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        tgt = batch["tokens"][:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll), {"nll": jnp.mean(nll), "aux": aux}

    # -- decode -------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        return {
            "self_k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "self_v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            # cross K/V are computed once at prefill from the encoder output
            "cross_k": jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
            "cross_v": jnp.zeros((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
        }

    def prefill_cross_kv(self, params, audio_embed, cache):
        """Populate cross K/V from the encoder (run once per request)."""
        cfg = self.cfg
        enc_out = self.encode(params, audio_embed)

        def one(lp):
            return attn_lib.cross_kv(lp["cross_attn"], enc_out, cfg)

        ck, cv = jax.vmap(one)(params["decoder"])
        return dict(cache, cross_k=ck.astype(cache["cross_k"].dtype),
                    cross_v=cv.astype(cache["cross_v"].dtype))

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        b = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0).astype(jnp.dtype(cfg.dtype))
        pe = sinusoidal_positions(1, cfg.d_model)  # placeholder, shifted below
        # position-dependent sinusoid for the current step
        div = jnp.exp(jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
                      * (-jnp.log(10000.0) / cfg.d_model))
        ang = pos.astype(jnp.float32) * div
        pe = jnp.zeros((cfg.d_model,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        x = x + pe.astype(x.dtype)

        def dec_layer(x, inp):
            lp, sk, sv, ck, cv = inp
            h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
            out, new_kv = attn_lib.attn_decode(lp["self_attn"], h, {"k": sk, "v": sv},
                                               pos, cfg, rope=False)
            x = x + out
            h = apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
            x = x + attn_lib.cross_attn_forward(lp["cross_attn"], h, ck, cv, cfg)
            h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
            x = x + apply_mlp(lp["mlp"], h, cfg.act, jnp.dtype(cfg.dtype))
            return x, (new_kv["k"], new_kv["v"])

        xs_in = (params["decoder"], cache["self_k"], cache["self_v"],
                 cache["cross_k"], cache["cross_v"])
        if not self.unroll:
            x, (nk, nv) = jax.lax.scan(dec_layer, x, xs_in)
        else:
            nks, nvs = [], []
            for li in range(cfg.n_layers):
                inp = jax.tree.map(lambda l, _li=li: l[_li], xs_in)
                x, (k1, v1) = dec_layer(x, inp)
                nks.append(k1)
                nvs.append(v1)
            nk, nv = jnp.stack(nks), jnp.stack(nvs)
        x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        logits = (x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32))[:, 0]
        return logits, dict(cache, self_k=nk, self_v=nv)

    # -- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        cfg = self.cfg
        b = shape.global_batch
        if shape.kind in ("train", "prefill"):
            return {
                "audio_embed": jax.ShapeDtypeStruct(
                    (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            }
        return {
            "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": jax.eval_shape(lambda: self.init_cache(b, shape.seq_len)),
        }
