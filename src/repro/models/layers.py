"""Shared building blocks: norms, activations, init, RoPE / M-RoPE, MLP."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, dim: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """Rotation angles [..., S, head_dim//2].

    positions: [B, S] for plain RoPE, or [3, B, S] (t/h/w streams) for M-RoPE.
    For M-RoPE, frequency slots are split into sections fed by the three
    position streams (Qwen2-VL Sec 3.2); sections must sum to head_dim//2.
    """
    inv = rope_freqs(head_dim, theta)  # [hd/2]
    if mrope_sections is None:
        return positions[..., None].astype(jnp.float32) * inv
    assert positions.ndim == 3 and positions.shape[0] == 3, "M-RoPE wants [3,B,S] positions"
    assert sum(mrope_sections) == head_dim // 2, (mrope_sections, head_dim)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(mrope_sections), total_repeat_length=head_dim // 2
    )  # [hd/2] -> which stream feeds each freq slot
    pos_per_slot = positions[sec_id]                      # [hd/2, B, S]
    ang = pos_per_slot.astype(jnp.float32) * inv[:, None, None]
    return jnp.moveaxis(ang, 0, -1)                       # [B, S, hd/2]


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, hd]; angles: [B, S, hd//2] -> rotated x."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def apply_mlp(params, x, act: str, compute_dtype=jnp.bfloat16):
    xc = x.astype(compute_dtype)
    g = activation(act)(xc @ params["wi_gate"].astype(compute_dtype))
    u = xc @ params["wi_up"].astype(compute_dtype)
    return ((g * u) @ params["wo"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe
