"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Two execution paths, same math:

- **local** (mesh=None): single-device reference — sort-based dispatch with
  a global capacity. The oracle for tests and the smoke-test path.
- **expert-parallel shard_map** (mesh given): tokens stay sharded over the
  data axes; routing, top-k and capacity are computed *per shard* (the
  standard EP formulation); a pair of ``all_to_all`` collectives moves
  grouped tokens expert-shard-wise ([E, C_loc, d] -> [E_loc, P·C_loc, d])
  and back. Expert weights are sharded over the ``model`` axis on the expert
  dimension. This keeps HLO FLOPs ≈ active-param FLOPs × capacity_factor —
  a pure-GSPMD lowering of scatter/sort dispatch instead replicates the
  token stream per device (measured 20× useful FLOPs at 128 experts).

Dispatch itself is sort-based, not one-hot-einsum: a [T, E, C] dispatch
einsum costs T·E·C·d MACs — orders of magnitude more than the useful expert
compute at E=128. Router runs in fp32. A Switch-style aux load-balance loss
is returned for training.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import activation, dense_init


def init_moe(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(keys[0], (d, e)),
        "wi_gate": dense_init(keys[1], (e, d, f)),
        "wi_up": dense_init(keys[2], (e, d, f)),
        "wo": dense_init(keys[3], (e, f, d)),
    }


def _route_and_group(xt, router, cfg: ModelConfig, capacity: int):
    """Shared routing + sort-based grouping. xt: [T, d].

    Returns (grouped [E, C, d], dest [T*k], st [T*k], sw [T*k], aux scalar).
    dest == E*C marks dropped slots.
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    grp_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - grp_start[se]
    keep = pos_in_e < capacity
    dest = jnp.where(keep, se * capacity + pos_in_e, e * capacity)

    xg = xt[st]
    buf = jnp.zeros((e * capacity + 1, d), xt.dtype).at[dest].set(xg)
    grouped = buf[: e * capacity].reshape(e, capacity, d)
    return grouped, dest, st, sw, aux


def _expert_ffn(grouped, wg, wu, wo, act_name: str):
    """grouped: [E?, C, d] x per-expert weights [E?, d, f] -> [E?, C, d]."""
    act = activation(act_name)
    h = act(jnp.einsum("ecd,edf->ecf", grouped, wg)) \
        * jnp.einsum("ecd,edf->ecf", grouped, wu)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _ungroup(out_g, dest, st, sw, t: int, d: int):
    """Scatter expert outputs back to tokens, gate-weighted."""
    e_cap = out_g.shape[0] * out_g.shape[1]
    out_flat = jnp.concatenate(
        [out_g.reshape(e_cap, d), jnp.zeros((1, d), out_g.dtype)], axis=0)
    per_slot = out_flat[dest] * sw[:, None].astype(out_g.dtype)
    return jnp.zeros((t, d), jnp.float32).at[st].add(
        per_slot.astype(jnp.float32))


def apply_moe(params, x, cfg: ModelConfig, *, mesh: Any = None,
              dp_axes: Tuple = ("data",), ep_axis: Optional[str] = "model"):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    ep_axis=None with a mesh -> pure data-parallel shard_map: experts
    replicated, routing/dispatch fully shard-local, zero collectives — the
    population-style layout for on-device-scale MoEs (§Perf pair 3).
    """
    b, s, d = x.shape
    compute_dtype = jnp.dtype(cfg.dtype)
    e, k = cfg.n_experts, cfg.top_k

    if mesh is not None and ep_axis is None:
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        b_shard = dp_axes if b % dp_size == 0 else None
        t_loc = (b // dp_size if b_shard else b) * s
        capacity = int(max(1, round(t_loc * k / e * cfg.capacity_factor)))

        def dp_moe(router, wg, wu, wo, xs):
            bl = xs.shape[0]
            xt = xs.reshape(bl * s, d).astype(compute_dtype)
            grouped, dest, st, sw, aux = _route_and_group(xt, router, cfg,
                                                          capacity)
            out_g = _expert_ffn(grouped, wg.astype(compute_dtype),
                                wu.astype(compute_dtype),
                                wo.astype(compute_dtype), cfg.act)
            out = _ungroup(out_g, dest, st, sw, bl * s, d)
            return (out.reshape(bl, s, d).astype(xs.dtype),
                    jax.lax.pmean(aux, dp_axes))

        fn = shard_map(dp_moe, mesh=mesh,
                       in_specs=(P(), P(), P(), P(), P(b_shard)),
                       out_specs=(P(b_shard), P()), check_rep=False)
        return fn(params["router"], params["wi_gate"], params["wi_up"],
                  params["wo"], x)

    if mesh is None:
        t = b * s
        capacity = int(max(1, round(t * k / e * cfg.capacity_factor)))
        xt = x.reshape(t, d).astype(compute_dtype)
        grouped, dest, st, sw, aux = _route_and_group(
            xt, params["router"], cfg, capacity)
        out_g = _expert_ffn(grouped, params["wi_gate"].astype(compute_dtype),
                            params["wi_up"].astype(compute_dtype),
                            params["wo"].astype(compute_dtype), cfg.act)
        out = _ungroup(out_g, dest, st, sw, t, d)
        return out.reshape(b, s, d).astype(x.dtype), aux

    # ---- expert-parallel shard_map path -----------------------------------
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    ep = mesh.shape[ep_axis]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    assert e % ep == 0, (e, ep)
    b_shard = dp_axes if b % dp_size == 0 else None
    t_loc = (b // dp_size if b_shard else b) * s
    # activations arrive replicated over the model axis (TP layout); each
    # expert-parallel peer takes a distinct 1/ep slice of the local tokens
    # (sequence-parallel split), so EP compute and bandwidth scale with ep.
    t_ep = max(t_loc // ep, 1)
    capacity = int(max(1, round(t_ep * k / e * cfg.capacity_factor)))

    def local_moe(router, wg, wu, wo, xs):
        # xs: [B_loc, S, d] tokens local to this data shard (replicated on ep)
        bl = xs.shape[0]
        xt = xs.reshape(bl * s, d).astype(compute_dtype)
        idx = jax.lax.axis_index(ep_axis)
        if t_loc >= ep:
            xt = jax.lax.dynamic_slice_in_dim(xt, idx * t_ep, t_ep, axis=0)
        grouped, dest, st, sw, aux = _route_and_group(xt, router, cfg, capacity)
        # [E, C, d] -> [E/ep, ep*C, d]: exchange groups with expert shards.
        # split_axis == concat_axis (device-major swap) keeps the a2a VJP
        # well-formed; layout bookkeeping is done with transposes.
        g4 = grouped.reshape(ep, e // ep, capacity, d)
        g4 = jax.lax.all_to_all(g4, ep_axis, split_axis=0, concat_axis=0,
                                tiled=False)   # [peer, E/ep, C, d]
        g4 = jnp.moveaxis(g4, 0, 1)            # [E/ep, peer, C, d]
        out_g = _expert_ffn(g4.reshape(e // ep, ep * capacity, d),
                            wg.astype(compute_dtype), wu.astype(compute_dtype),
                            wo.astype(compute_dtype), cfg.act)
        o4 = jnp.moveaxis(out_g.reshape(e // ep, ep, capacity, d), 1, 0)
        o4 = jax.lax.all_to_all(o4, ep_axis, split_axis=0, concat_axis=0,
                                tiled=False)   # [expert-owner, E/ep, C, d]
        out = _ungroup(o4.reshape(e, capacity, d), dest, st, sw, xt.shape[0], d)
        if t_loc >= ep:
            out = jax.lax.all_gather(out, ep_axis, axis=0, tiled=True)
            out = out[: bl * s]
        aux = jax.lax.pmean(jax.lax.pmean(aux, ep_axis), dp_axes)
        return out.reshape(bl, s, d).astype(xs.dtype), aux

    in_specs = (P(), P(ep_axis), P(ep_axis), P(ep_axis), P(b_shard))
    out_specs = (P(b_shard), P())
    fn = shard_map(local_moe, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(params["router"], params["wi_gate"], params["wi_up"],
              params["wo"], x)
