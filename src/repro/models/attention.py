"""GQA attention layer: full-sequence (train/prefill) and KV-cache decode.

Supports QKV bias (qwen), sliding windows (gemma3 local layers; rolling KV
cache at decode), RoPE and M-RoPE (qwen2-vl), cross-attention (whisper).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.models.layers import apply_rope, dense_init, rope_angles

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, cfg.n_heads * hd)),
        "wk": dense_init(k2, (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(k3, (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(k4, (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _qkv(params, x, cfg: ModelConfig, compute_dtype):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(compute_dtype)
    q = xc @ params["wq"].astype(compute_dtype)
    k = xc @ params["wk"].astype(compute_dtype)
    v = xc @ params["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.n_kv_heads, hd),
            v.reshape(b, s, cfg.n_kv_heads, hd))


def _constrain_heads(t, head_axis: Optional[str]):
    """Shard the head dim of [B,S,H,hd] over `head_axis` (GSPMD pads when the
    head count doesn't divide — how non-divisible TP stays score-AR-free)."""
    if head_axis is None:
        return t
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(*([None] * (t.ndim - 2) + [head_axis, None])))
    except (ValueError, RuntimeError, TypeError):
        return t


def _constrain_seq(t, seq_axis: Optional[str]):
    """Context parallelism: shard the sequence dim of [B,S,H,hd] over
    `seq_axis`; GSPMD all-gathers K/V where attention needs them (the
    Llama3-style CP layout for head counts that don't divide the TP axis)."""
    if seq_axis is None:
        return t
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(None, seq_axis, None, None))
    except (ValueError, RuntimeError, TypeError):
        return t


def attn_forward(params, x, positions, cfg: ModelConfig, *,
                 window: Optional[int] = None, causal: bool = True,
                 backend: str = "ref", rope: bool = True,
                 head_axis: Optional[str] = None,
                 seq_axis: Optional[str] = None) -> jnp.ndarray:
    """Full-sequence self-attention. positions: [B,S] or [3,B,S] (M-RoPE)."""
    compute_dtype = jnp.dtype(cfg.dtype)
    q, k, v = _qkv(params, x, cfg, compute_dtype)
    q = _constrain_heads(q, head_axis)
    k = _constrain_heads(k, head_axis if cfg.n_kv_heads > 1 else None)
    v = _constrain_heads(v, head_axis if cfg.n_kv_heads > 1 else None)
    q = _constrain_seq(q, seq_axis)
    hd = cfg.resolved_head_dim
    if rope:
        ang = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
    out = flash_attention(q, k, v, causal=causal, window=window, backend=backend)
    b, s, _, _ = out.shape
    out = out.reshape(b, s, cfg.n_heads * hd).astype(compute_dtype)
    return (out @ params["wo"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  window: Optional[int] = None, dtype=jnp.bfloat16):
    """Cache for ONE attention layer. Rolling buffer when windowed."""
    hd = cfg.resolved_head_dim
    slots = min(window, max_seq) if window is not None else max_seq
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
    }


def attn_decode(params, x, cache, pos, cfg: ModelConfig, *,
                window: Optional[int] = None, rope: bool = True):
    """One-token decode. x: [B,1,D]; pos: scalar int32 (current position).

    Cached K/V are stored post-RoPE. For windowed layers the cache is a
    rolling buffer of ``window`` slots written at ``pos % window``.
    """
    compute_dtype = jnp.dtype(cfg.dtype)
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions, (3, b, 1))
    q, k, v = _qkv(params, x, cfg, compute_dtype)
    if rope:
        ang = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, ang)      # [B,1,H,hd]
        k = apply_rope(k, ang)      # [B,1,KV,hd]

    slots = cache["k"].shape[1]
    slot = pos % slots if window is not None else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    # attention over the cache (linear in cache length)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    sc = jnp.einsum("bikgd,bjkd->bkgj", qg * scale, ck.astype(jnp.float32))  # [B,KV,G,slots]
    slot_idx = jnp.arange(slots)
    if window is not None:
        # slot s holds position p ≡ s (mod slots), the largest such p ≤ pos
        slot_pos = pos - ((pos - slot_idx) % slots)
        valid = (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - window)
    else:
        valid = slot_idx <= pos
    sc = jnp.where(valid[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, cv.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.n_heads * hd).astype(compute_dtype)
    out = (out @ params["wo"].astype(compute_dtype)).astype(x.dtype)
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_forward(params, x, enc_k, enc_v, cfg: ModelConfig):
    """x: [B,S,D] queries; enc_k/enc_v: [B,Se,KV,hd] precomputed from encoder."""
    compute_dtype = jnp.dtype(cfg.dtype)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(compute_dtype)
    q = (xc @ params["wq"].astype(compute_dtype)).reshape(b, s, cfg.n_heads, hd)
    out = flash_attention(q, enc_k, enc_v, causal=False, backend="ref")
    out = out.reshape(b, s, cfg.n_heads * hd).astype(compute_dtype)
    return (out @ params["wo"].astype(compute_dtype)).astype(x.dtype)


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output."""
    compute_dtype = jnp.dtype(cfg.dtype)
    b, se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    e = enc_out.astype(compute_dtype)
    k = (e @ params["wk"].astype(compute_dtype)).reshape(b, se, cfg.n_kv_heads, hd)
    v = (e @ params["wv"].astype(compute_dtype)).reshape(b, se, cfg.n_kv_heads, hd)
    return k, v
