"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, truly recurrent), alternating per config.

mLSTM training path uses a chunked parallel form (flash-style running
rescale) so 32k+ sequences never materialize [S,S]:
    d_ij = cumF_i - cumF_j + ĩ_j   (j <= i),  separable as cumF_i + b_j
    h_i  = Σ_j (q_i·k_j/√P) e^{d_ij - m_i} v_j / max(|den_i|, e^{-m_i})
with m_i = max_j d_ij. The recurrent decode form (C, n, m states) matches it
exactly (validated in tests).

sLSTM keeps head-wise recurrent weights R and is computed with a lax.scan
over time (the honest sequential dependency of the architecture).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import apply_norm, dense_init, init_norm

log_sigmoid = jax.nn.log_sigmoid


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    dp = int(cfg.xlstm_proj_factor * d)
    h = cfg.n_heads
    p = dp // h
    return d, dp, h, p


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig):
    d, dp, h, p = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": init_norm(cfg.norm, d),
        "w_up": dense_init(ks[0], (d, dp)),
        "w_gate": dense_init(ks[1], (d, dp)),
        "wq": dense_init(ks[2], (dp, dp)),
        "wk": dense_init(ks[3], (dp, dp)),
        "wv": dense_init(ks[4], (dp, dp)),
        "w_if": dense_init(ks[5], (dp, 2 * h)),  # i and f gate pre-activations
        "gn_scale": jnp.ones((dp,), jnp.float32),
        "w_down": dense_init(ks[7], (dp, d)),
    }


def _mlstm_parallel(q, k, v, i_pre, f_pre, *, block: int = 256):
    """q,k,v: [B,S,H,P]; i_pre,f_pre: [B,S,H] -> h [B,S,H,P] (fp32).

    Chunked two-level scan with running (m, num, den) rescaling.
    """
    b, s, h, p = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(p))
    logf = log_sigmoid(f_pre.astype(jnp.float32))
    cumf = jnp.cumsum(logf, axis=1)                        # [B,S,H]
    bj = i_pre.astype(jnp.float32) - cumf                  # [B,S,H]

    block = min(block, s)
    nb = -(-s // block)
    pad = nb * block - s

    def pad_t(t, fill=0.0):
        cfgpad = [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2)
        return jnp.pad(t, cfgpad, constant_values=fill) if pad else t

    # keep block operands in bf16 (halves HBM traffic of the dominant
    # score/value reads); accumulation below stays fp32
    blk_dtype = q.dtype if q.dtype == jnp.bfloat16 else jnp.float32
    qc = pad_t(q).reshape(b, nb, block, h, p).astype(blk_dtype)
    kc = pad_t(k).reshape(b, nb, block, h, p).astype(blk_dtype)
    vc = pad_t(v).reshape(b, nb, block, h, p).astype(blk_dtype)
    bjc = pad_t(bj, fill=-1e30).reshape(b, nb, block, h)
    cumfc = pad_t(cumf).reshape(b, nb, block, h)

    def one_q_block(qi):
        q_blk = qc[:, qi]                                  # [B,Q,H,P]
        cf_i = cumfc[:, qi]                                # [B,Q,H]
        qpos = qi * block + jnp.arange(block)

        # d_ij = cf_i + b_j is separable: keep everything in [B,Q,H]/[B,K,H]
        # factors plus the unavoidable [B,H,Q,K] score matrix. A running
        # column max (mb) keeps exp(b_j - mb) bounded.
        def off_diag_step(carry, kj):
            m_prev, num, den = carry
            k_blk, v_blk, b_blk = kc[:, kj], vc[:, kj], bjc[:, kj]
            mb = jnp.max(b_blk, axis=1)                    # [B,H]
            m_new = jnp.maximum(m_prev, cf_i + mb[:, None, :])
            corr = jnp.exp(m_prev - m_new)                 # [B,Q,H]
            sc = jnp.einsum("bihp,bjhp->bhij", q_blk, k_blk) * scale
            row = jnp.exp(cf_i - m_new + mb[:, None, :])   # [B,Q,H]
            col = jnp.exp(b_blk - mb[:, None, :])          # [B,K,H]
            sw = sc * jnp.moveaxis(row, 2, 1)[..., None] \
                * jnp.moveaxis(col, 2, 1)[:, :, None, :]   # [B,H,Q,K]
            num = num * corr[..., None] + jnp.einsum("bhij,bjhp->bihp", sw, v_blk)
            den = den * corr + jnp.moveaxis(jnp.sum(sw, axis=-1), 1, 2)
            return (m_new, num, den), None

        m0 = jnp.full((b, block, h), -1e30, jnp.float32)
        num0 = jnp.zeros((b, block, h, p), jnp.float32)
        den0 = jnp.zeros((b, block, h), jnp.float32)
        carry = (m0, num0, den0)
        if qi > 0:
            carry, _ = jax.lax.scan(off_diag_step, carry, jnp.arange(qi))

        # diagonal block: prefix-max over j <= i
        m_prev, num, den = carry
        k_blk, v_blk, b_blk = kc[:, qi], vc[:, qi], bjc[:, qi]
        cmax = jax.lax.cummax(b_blk, axis=1)               # [B,K,H] prefix max
        m_new = jnp.maximum(m_prev, cf_i + cmax)           # row i uses cmax[i]
        corr = jnp.exp(m_prev - m_new)
        sc = jnp.einsum("bihp,bjhp->bhij", q_blk, k_blk) * scale
        maskij = (jnp.arange(block)[None, :] <= jnp.arange(block)[:, None])
        # w_ij = exp(cf_i + b_j - m_new_i); on the diagonal the exponent is
        # bounded <= 0 because m_new_i >= cf_i + b_j for j <= i.
        w = jnp.exp(jnp.minimum(
            cf_i[:, :, None, :] + b_blk[:, None, :, :] - m_new[:, :, None, :],
            0.0))
        w = jnp.where(maskij[None, :, :, None], w, 0.0)
        sw = sc * jnp.moveaxis(w, 3, 1)
        num = num * corr[..., None] + jnp.einsum("bhij,bjhp->bihp", sw, v_blk)
        den = den * corr + jnp.moveaxis(jnp.sum(sw, axis=-1), 1, 2)
        m, num, den = m_new, num, den
        hvec = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return hvec

    # q blocks have data-dependent inner lengths -> python loop (static nb)
    outs = [one_q_block(qi) for qi in range(nb)]
    out = jnp.concatenate(outs, axis=1)[:, :s]
    return out


def mlstm_forward(params, x, cfg: ModelConfig, *, block: int = 256):
    d, dp, h, p = _dims(cfg)
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz, s, _ = x.shape
    xn = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
    u = (xn.astype(compute_dtype) @ params["w_up"].astype(compute_dtype))
    gate = (xn.astype(compute_dtype) @ params["w_gate"].astype(compute_dtype))
    q = (u @ params["wq"].astype(compute_dtype)).reshape(bsz, s, h, p)
    k = (u @ params["wk"].astype(compute_dtype)).reshape(bsz, s, h, p)
    v = (u @ params["wv"].astype(compute_dtype)).reshape(bsz, s, h, p)
    if_pre = (u @ params["w_if"].astype(compute_dtype)).astype(jnp.float32)
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)
    hv = _mlstm_parallel(q, k, v, i_pre, f_pre, block=block)   # [B,S,H,P] fp32
    hv = hv.reshape(bsz, s, dp)
    # per-head group norm
    hg = hv.reshape(bsz, s, h, p)
    mu = jnp.mean(hg, axis=-1, keepdims=True)
    var = jnp.var(hg, axis=-1, keepdims=True)
    hg = (hg - mu) * jax.lax.rsqrt(var + 1e-6)
    hv = hg.reshape(bsz, s, dp) * params["gn_scale"]
    out = hv.astype(compute_dtype) * jax.nn.silu(gate)
    return x + (out @ params["w_down"].astype(compute_dtype)).astype(x.dtype)


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    _, dp, h, p = _dims(cfg)
    return {
        "C": jnp.zeros((batch, h, p, p), dtype),
        "n": jnp.zeros((batch, h, p), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
    }


def mlstm_decode(params, x, cache, cfg: ModelConfig):
    d, dp, h, p = _dims(cfg)
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz = x.shape[0]
    xn = apply_norm(params["norm"], x[:, 0], cfg.norm, cfg.norm_eps)
    u = xn.astype(compute_dtype) @ params["w_up"].astype(compute_dtype)
    gate = xn.astype(compute_dtype) @ params["w_gate"].astype(compute_dtype)
    q = (u @ params["wq"].astype(compute_dtype)).reshape(bsz, h, p).astype(jnp.float32)
    k = (u @ params["wk"].astype(compute_dtype)).reshape(bsz, h, p).astype(jnp.float32)
    v = (u @ params["wv"].astype(compute_dtype)).reshape(bsz, h, p).astype(jnp.float32)
    if_pre = (u @ params["w_if"].astype(compute_dtype)).astype(jnp.float32)
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)              # [B,H]
    logf = log_sigmoid(f_pre)
    m_prev, C, n = cache["m"].astype(jnp.float32), cache["C"].astype(jnp.float32), cache["n"].astype(jnp.float32)
    m_new = jnp.maximum(logf + m_prev, i_pre)
    f_act = jnp.exp(logf + m_prev - m_new)
    i_act = jnp.exp(i_pre - m_new)
    scale = 1.0 / jnp.sqrt(jnp.float32(p))
    C = C * f_act[..., None, None] + i_act[..., None, None] * jnp.einsum("bhp,bhq->bhpq", v, k)
    n = n * f_act[..., None] + i_act[..., None] * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q * scale)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q * scale)), jnp.exp(-m_new))
    hv = num / den[..., None]                                  # [B,H,P]
    mu = jnp.mean(hv, axis=-1, keepdims=True)
    var = jnp.var(hv, axis=-1, keepdims=True)
    hv = (hv - mu) * jax.lax.rsqrt(var + 1e-6)
    hv = hv.reshape(bsz, dp) * params["gn_scale"]
    out = hv.astype(compute_dtype) * jax.nn.silu(gate)
    out = (out @ params["w_down"].astype(compute_dtype)).astype(x.dtype)
    new_cache = {"C": C.astype(cache["C"].dtype), "n": n.astype(cache["n"].dtype),
                 "m": m_new.astype(cache["m"].dtype)}
    return x + out[:, None, :], new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig):
    d, dp, h, p = _dims(cfg)
    ks = jax.random.split(key, 10)
    up = int(cfg.xlstm_proj_factor * d)
    return {
        "norm": init_norm(cfg.norm, d),
        "w_in": dense_init(ks[0], (d, 4 * d)),               # z,i,f,o inputs
        "r": dense_init(ks[1], (4, cfg.n_heads, d // cfg.n_heads, d // cfg.n_heads),
                        scale=0.02),                          # head-wise recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_up_gate": dense_init(ks[2], (d, up)),
        "w_up": dense_init(ks[3], (d, up)),
        "w_down": dense_init(ks[4], (up, d)),
    }


def _slstm_cell(params, xz, xi, xf, xo, state, n_heads):
    """One time step. x*: [B, D] gate pre-activations; state: dict of [B,H,P]."""
    h_prev, c_prev, n_prev, m_prev = state["h"], state["c"], state["n"], state["m"]
    b, hh, p = h_prev.shape
    r = params["r"]                                           # [4, H, P, P]

    def rec(w, hp):
        return jnp.einsum("bhp,hpq->bhq", hp, w)

    z_pre = xz.reshape(b, hh, p) + rec(r[0], h_prev)
    i_pre = xi.reshape(b, hh, p) + rec(r[1], h_prev)
    f_pre = xf.reshape(b, hh, p) + rec(r[2], h_prev)
    o_pre = xo.reshape(b, hh, p) + rec(r[3], h_prev)
    z = jnp.tanh(z_pre)
    m_new = jnp.maximum(log_sigmoid(f_pre) + m_prev, i_pre)
    i_act = jnp.exp(i_pre - m_new)
    f_act = jnp.exp(log_sigmoid(f_pre) + m_prev - m_new)
    c = f_act * c_prev + i_act * z
    n = f_act * n_prev + i_act
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"h": h_new, "c": c, "n": n, "m": m_new}


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d, _, _, _ = _dims(cfg)
    h = cfg.n_heads
    p = d // h
    z = jnp.zeros((batch, h, p), dtype)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, h, p), -1e30, dtype)}


def slstm_forward(params, x, cfg: ModelConfig, *, backend: str = "ref"):
    """True sequential recurrence over time (fused Pallas kernel on TPU:
    recurrent weights stay VMEM-resident across the sweep — see
    repro/kernels/slstm_fused)."""
    from repro.kernels.slstm_fused.ops import slstm_scan

    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz, s, _ = x.shape
    xn = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
    pre = (xn.astype(compute_dtype) @ params["w_in"].astype(compute_dtype)).astype(jnp.float32)
    pre = pre + params["b"]
    pre = pre.reshape(bsz, s, 4, h, p)                        # (z,i,f,o) blocks
    hs = slstm_scan(pre, params["r"], backend=backend)        # [B,S,H,P]
    hv = hs.reshape(bsz, s, d)
    # group norm per head
    hg = hv.reshape(bsz, s, h, d // h)
    mu = jnp.mean(hg, axis=-1, keepdims=True)
    var = jnp.var(hg, axis=-1, keepdims=True)
    hv = ((hg - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(bsz, s, d) * params["gn_scale"]
    hv = hv.astype(compute_dtype)
    up = jax.nn.gelu(hv @ params["w_up_gate"].astype(compute_dtype)) * (
        hv @ params["w_up"].astype(compute_dtype))
    return x + (up @ params["w_down"].astype(compute_dtype)).astype(x.dtype)


def slstm_decode(params, x, cache, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz = x.shape[0]
    xn = apply_norm(params["norm"], x[:, 0], cfg.norm, cfg.norm_eps)
    pre = (xn.astype(compute_dtype) @ params["w_in"].astype(compute_dtype)).astype(jnp.float32)
    pre = pre + params["b"]
    xz, xi, xf, xo = jnp.split(pre, 4, axis=-1)
    new_state = _slstm_cell(params, xz, xi, xf, xo,
                            {k: v.astype(jnp.float32) for k, v in cache.items()}, h)
    hv = new_state["h"].reshape(bsz, h, d // h)
    mu = jnp.mean(hv, axis=-1, keepdims=True)
    var = jnp.var(hv, axis=-1, keepdims=True)
    hv = ((hv - mu) * jax.lax.rsqrt(var + 1e-6)).reshape(bsz, d) * params["gn_scale"]
    hv = hv.astype(compute_dtype)
    up = jax.nn.gelu(hv @ params["w_up_gate"].astype(compute_dtype)) * (
        hv @ params["w_up"].astype(compute_dtype))
    out = (up @ params["w_down"].astype(compute_dtype)).astype(x.dtype)
    cache_new = {k: v.astype(cache[k].dtype) for k, v in new_state.items()}
    return x + out[:, None, :], cache_new
