"""Mamba2 (SSD) block — used by zamba2 and available standalone.

Structure follows Mamba2: input projections -> [z | x | B | C | dt]; causal
depthwise conv over (x, B, C); silu; SSD scan; gated RMSNorm; out_proj.
B/C are shared across heads; A is a negative scalar per head; dt via
softplus(dt + bias).

TP note: projections are kept *separate* (w_z/w_x/w_B/w_C/w_dt) rather than
one fused in_proj so tensor parallelism can shard the inner channel dim
(= SSM heads) over the ``model`` mesh axis while B/C (state dim, shared
across heads) stay replicated — the head-parallel Mamba TP layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.kernels.ssm_scan.ops import ssd_scan
from repro.models.layers import dense_init


def _dims(cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    d_in = cfg.ssm_expand * d
    n_heads = d_in // cfg.ssm_head_dim
    return d, d_in, n_heads


def init_mamba2(key, cfg: ModelConfig, d_model=None):
    d, d_in, h = _dims(cfg, d_model)
    n = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, d_in)),
        "w_x": dense_init(ks[1], (d, d_in)),
        "w_B": dense_init(ks[2], (d, n)),
        "w_C": dense_init(ks[3], (d, n)),
        "w_dt": dense_init(ks[4], (d, h)),
        "conv_x_w": dense_init(ks[5], (cfg.ssm_conv, d_in), scale=0.1),
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_B_w": dense_init(ks[6], (cfg.ssm_conv, n), scale=0.1),
        "conv_B_b": jnp.zeros((n,), jnp.float32),
        "conv_C_w": dense_init(ks[7], (cfg.ssm_conv, n), scale=0.1),
        "conv_C_b": jnp.zeros((n,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d)),
    }


def _causal_depthwise_conv(x, w, b):
    """x: [B,S,C]; w: [K,C] -> causal depthwise conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _gated_norm(y, z, scale, eps=1e-6):
    g = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    return (g.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def mamba2_forward(params, x, cfg: ModelConfig, *, backend: str = "ref",
                   chunk: int = 64):
    """x: [B,S,D] -> [B,S,D]."""
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz, s, d = x.shape
    _, d_in, h = _dims(cfg, d)
    xc = x.astype(compute_dtype)
    z = xc @ params["w_z"].astype(compute_dtype)
    xs = xc @ params["w_x"].astype(compute_dtype)
    Bm = xc @ params["w_B"].astype(compute_dtype)
    Cm = xc @ params["w_C"].astype(compute_dtype)
    dt_raw = xc @ params["w_dt"].astype(compute_dtype)

    xs = jax.nn.silu(_causal_depthwise_conv(
        xs.astype(jnp.float32), params["conv_x_w"], params["conv_x_b"]))
    Bm = jax.nn.silu(_causal_depthwise_conv(
        Bm.astype(jnp.float32), params["conv_B_w"], params["conv_B_b"]))
    Cm = jax.nn.silu(_causal_depthwise_conv(
        Cm.astype(jnp.float32), params["conv_C_w"], params["conv_C_b"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])      # [B,S,H]
    A = -jnp.exp(params["A_log"])                                             # [H]
    xh = xs.reshape(bsz, s, h, cfg.ssm_head_dim)
    y, _ = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk, backend=backend)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, d_in)
    y = _gated_norm(y, z.astype(jnp.float32), params["norm_scale"])
    return (y.astype(compute_dtype) @ params["out_proj"].astype(compute_dtype)).astype(x.dtype)


# ---------------------------------------------------------------------------
# decode (single token, recurrent state)
# ---------------------------------------------------------------------------


def init_mamba2_cache(cfg: ModelConfig, batch: int, d_model=None, dtype=jnp.float32):
    _, d_in, h = _dims(cfg, d_model)
    n = cfg.ssm_state
    k = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, k - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, k - 1, n), dtype),
        "conv_C": jnp.zeros((batch, k - 1, n), dtype),
        "ssm": jnp.zeros((batch, h, cfg.ssm_head_dim, n), dtype),
    }


def _conv_step(hist, new, w, b):
    """hist: [B,K-1,C]; new: [B,C] -> (conv output [B,C], new hist)."""
    full = jnp.concatenate([hist, new[:, None, :].astype(hist.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32), w) + b
    return out, full[:, 1:]


def mamba2_decode(params, x, cache, cfg: ModelConfig):
    """x: [B,1,D] -> (y [B,1,D], new cache). O(1) in context length."""
    compute_dtype = jnp.dtype(cfg.dtype)
    bsz, _, d = x.shape
    _, d_in, h = _dims(cfg, d)
    xc = x[:, 0].astype(compute_dtype)
    z = xc @ params["w_z"].astype(compute_dtype)
    xs_new = xc @ params["w_x"].astype(compute_dtype)
    B_new = xc @ params["w_B"].astype(compute_dtype)
    C_new = xc @ params["w_C"].astype(compute_dtype)
    dt_raw = xc @ params["w_dt"].astype(compute_dtype)

    xs, conv_x = _conv_step(cache["conv_x"], xs_new, params["conv_x_w"], params["conv_x_b"])
    Bm, conv_B = _conv_step(cache["conv_B"], B_new, params["conv_B_w"], params["conv_B_b"])
    Cm, conv_C = _conv_step(cache["conv_C"], C_new, params["conv_C_w"], params["conv_C_b"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])      # [B,H]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                             # [B,H]
    xh = xs.reshape(bsz, h, cfg.ssm_head_dim)
    state = cache["ssm"].astype(jnp.float32)
    state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", dt[..., None] * xh, Bm)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + params["D"][None, :, None] * xh
    y = y.reshape(bsz, d_in)
    y = _gated_norm(y, z.astype(jnp.float32), params["norm_scale"])
    y = (y.astype(compute_dtype) @ params["out_proj"].astype(compute_dtype))
    return y[:, None, :].astype(x.dtype), {
        "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
        "ssm": state.astype(cache["ssm"].dtype)}
