"""gemma3-4b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144. Local layers use a
1024-token sliding window; every 6th layer is global — which makes long_500k
decode tractable (only 6 global KV caches at full length).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    source="[hf:google/gemma-3-1b-pt]",
    head_dim=256,
    sliding_window=1024,
    global_layer_interval=6,   # 5 local : 1 global
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        head_dim=32,
        sliding_window=64,
        global_layer_interval=2,
        norm="rmsnorm",
        act="gelu",
        tie_embeddings=True,
    )
