"""granite-moe-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512(per expert) vocab=49155,
MoE 32e top-8.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
    n_experts=32,
    top_k=8,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=256,
        n_experts=4,
        top_k=2,
        norm="rmsnorm",
        act="silu",
        tie_embeddings=True,
    )
