"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324].

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    source="[arXiv:2405.04324]",
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke",
        family="dense",
        n_layers=2,
        d_model=192,
        n_heads=6,
        n_kv_heads=1,
        d_ff=768,
        vocab=256,
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
    )
