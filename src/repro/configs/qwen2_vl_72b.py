"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. The ViT vision
encoder + projector is a STUB per the assignment carve-out: ``input_specs``
feeds precomputed patch embeddings (`vision_tokens` prefix) of shape
(batch, vision_tokens, d_model) to the language backbone.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    source="[arXiv:2409.12191]",
    qkv_bias=True,
    mrope_sections=(16, 24, 24),   # t/h/w sections of head_dim//2 = 64 (HF value)
    rope_theta=1_000_000.0,
    vision_tokens=256,
    norm="rmsnorm",
    act="silu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=256,
        qkv_bias=True,
        mrope_sections=(4, 6, 6),   # head_dim//2 = 16
        vision_tokens=16,
        norm="rmsnorm",
        act="silu",
    )
