"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own internal up-projection (proj factor 2) instead of a separate MLP.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    source="[arXiv:2405.04517]",
    xlstm_slstm_every=2,      # alternate mLSTM / sLSTM
    xlstm_proj_factor=2.0,
    norm="layernorm",
    act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke",
        family="xlstm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=256,
        xlstm_slstm_every=2,
        xlstm_proj_factor=2.0,
        norm="layernorm",
        act="gelu",
    )
