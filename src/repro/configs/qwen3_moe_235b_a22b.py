"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

94L d_model=4096 64H (GQA kv=4) d_ff=1536(per expert) vocab=151936,
MoE 128e top-8.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    source="[hf:Qwen/Qwen3-30B-A3B]",
    head_dim=128,
    n_experts=128,
    top_k=8,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=256,
        head_dim=32,
        n_experts=4,
        top_k=2,
        norm="rmsnorm",
        act="silu",
    )
