"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Every 6th layer applies the *shared* attention block (single weight set reused
at every application, Zamba's signature trick).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    source="[arXiv:2411.15242]",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_layer_interval=6,
    norm="rmsnorm",
    act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=256,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=32,
        attn_layer_interval=2,
        norm="rmsnorm",
        act="gelu",
    )
