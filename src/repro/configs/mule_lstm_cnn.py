"""The paper's LSTM-CNN for IMU human-activity recognition (Sec 4.3.1).

"To handle sequential IMU data, we employ an LSTM-CNN model structure, which
is well-established in HAR research [47]" (Xia et al. 2020: conv1d blocks over
the 50 Hz window followed by LSTM layers and a dense classifier).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class LSTMCNNConfig:
    name: str = "mule-lstm-cnn"
    window: int = 128          # 50 Hz IMU samples per window
    channels: int = 6          # 3-axis accel + 3-axis gyro
    conv_features: Tuple[int, int] = (32, 64)
    lstm_hidden: int = 64
    n_classes: int = 4         # Bike Repair / Cooking / Dance / Music (Table 2)
    source = "[paper Sec 4.3.1, Xia et al. 2020]"


CONFIG = LSTMCNNConfig()


def smoke_config() -> LSTMCNNConfig:
    return LSTMCNNConfig(name="mule-lstm-cnn-smoke", window=32, conv_features=(8, 16), lstm_hidden=16, n_classes=4)
