"""whisper-base — enc-dec ASR backbone, conv frontend STUB [arXiv:2212.04356].

6L(dec)+6L(enc) d_model=512 8H (kv=8) d_ff=2048 vocab=51865. The
mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs`` provides precomputed frame embeddings of shape
(batch, encoder_seq=1500, d_model).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    source="[arXiv:2212.04356]",
    encoder_layers=6,
    encoder_seq=1500,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=256,
        encoder_layers=2,
        encoder_seq=64,
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
    )
