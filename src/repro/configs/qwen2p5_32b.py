"""qwen2.5-32b — dense, GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    source="[hf:Qwen/Qwen2.5-0.5B]",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        n_layers=2,
        d_model=160,
        n_heads=5,
        n_kv_heads=1,
        d_ff=432,
        vocab=256,
        qkv_bias=True,
        norm="rmsnorm",
        act="silu",
    )
