"""stablelm-1.6b — dense decoder [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    source="[hf:stabilityai/stablelm-2-1_6b]",
    rope_theta=10_000.0,
    norm="layernorm",
    act="silu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=352,
        vocab=256,
        norm="layernorm",
        act="silu",
    )
