"""Config system: ModelConfig dataclass + architecture registry.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact full-scale config) and ``smoke_config()`` (a reduced
variant of the same family for CPU smoke tests: <=2 layers, d_model<=512,
<=4 experts).

Select with ``repro.configs.get_config("<arch-id>")`` or ``--arch <id>`` in
the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description consumed by repro.models.

    A single config class covers all six assigned families (dense / moe /
    ssm / hybrid / vlm / audio); family-specific fields default to "off".
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""                 # citation, e.g. "[arXiv:2405.04517]"

    # -- attention details ---------------------------------------------------
    head_dim: Optional[int] = None   # default: d_model // n_heads
    qkv_bias: bool = False           # Qwen2-style QKV bias
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # window size for local layers
    global_layer_interval: int = 0   # gemma3: every k-th layer is global
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # -- SSM (Mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_layer_interval: int = 0     # zamba2: shared attn block every k layers

    # -- xLSTM ---------------------------------------------------------------
    xlstm_slstm_every: int = 0       # alternate sLSTM blocks every k blocks
    xlstm_proj_factor: float = 2.0   # internal up-projection factor

    # -- enc-dec (whisper) -----------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed encoder length (stub frontend)

    # -- vlm stub --------------------------------------------------------------
    vision_tokens: int = 0           # patch-embedding stub prefix length

    # -- misc ------------------------------------------------------------------
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid/xLSTM, or sliding-window dense."""
        if self.family in ("ssm", "hybrid", "xlstm"):
            return True
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for rooflines."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        hd = self.resolved_head_dim
        for layer in range(self.n_layers):
            if self.family in ("ssm", "hybrid") and not self._is_attn_layer(layer):
                d_in = self.ssm_expand * d
                n_heads_ssm = d_in // self.ssm_head_dim
                # in_proj (z,x,B,C,dt) + conv + out_proj, Mamba2 layout
                n += d * (2 * d_in + 2 * self.ssm_state + n_heads_ssm)
                n += self.ssm_conv * (d_in + 2 * self.ssm_state)
                n += d_in * d + 2 * n_heads_ssm  # out_proj + A,D
            elif self.family == "xlstm":
                pass  # handled below
            else:
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                n += q + kv + o
                if self.n_experts:
                    n += d * self.n_experts  # router
                    n += self.n_experts * 3 * d * self.d_ff
                elif self.d_ff:
                    n += 3 * d * self.d_ff
        if self.family == "xlstm":
            # mLSTM/sLSTM blocks with proj factor
            dp = int(self.xlstm_proj_factor * d)
            per_block = d * dp * 2 + dp * d + 4 * dp * (dp // max(self.n_heads, 1))
            n += self.n_layers * per_block
        if self.encoder_layers:
            n += self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        expert_params = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return total - expert_params + active

    def _is_attn_layer(self, layer: int) -> bool:
        if self.family == "hybrid" and self.attn_layer_interval:
            return (layer + 1) % self.attn_layer_interval == 0
        return self.family not in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "xlstm-350m",
    "zamba2-2.7b",
    "stablelm-1.6b",
    "qwen3-moe-235b-a22b",
    "granite-34b",
    "qwen2-vl-72b",
    "granite-moe-1b-a400m",
    "qwen2.5-32b",
    "gemma3-4b",
    "whisper-base",
)

_MODULE_FOR: dict[str, str] = {
    "xlstm-350m": "xlstm_350m",
    "zamba2-2.7b": "zamba2_2p7b",
    "stablelm-1.6b": "stablelm_1p6b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-34b": "granite_34b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2.5-32b": "qwen2p5_32b",
    "gemma3-4b": "gemma3_4b",
    "whisper-base": "whisper_base",
    # the paper's own models
    "mule-cnn": "mule_cnn",
    "mule-lstm-cnn": "mule_lstm_cnn",
}


def get_config(arch_id: str) -> ModelConfig:
    """Full-scale config for an architecture id."""
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.smoke_config()


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
