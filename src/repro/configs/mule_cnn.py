"""The paper's lightweight CNN for the CIFAR-100 super-class task (Sec 4.2.1).

"a feature extractor with two convolutional blocks (3x3 convolution, batch
normalization, ReLU activation, and pooling) and a classifier with two fully
connected layers" — used by every fixed/mobile device in the ML Mule
simulations. Described by a small dict config (it is not a transformer).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "mule-cnn"
    image_size: int = 32
    channels: int = 3
    conv_features: Tuple[int, int] = (32, 64)
    hidden: int = 128
    n_classes: int = 20
    source = "[paper Sec 4.2.1]"


CONFIG = CNNConfig()


def smoke_config() -> CNNConfig:
    return CNNConfig(name="mule-cnn-smoke", image_size=16, conv_features=(8, 16), hidden=32, n_classes=4)
