"""Pytree checkpointing: flattened leaves -> npz + json metadata.

Checkpoints carry ML Mule lineage metadata (model last-update timestamps)
so the freshness filter survives restarts — a mule that reboots still knows
how stale its snapshot is.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        out[name] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **_paths(tree))
    meta = dict(metadata or {})
    meta["step"] = step
    with open(path + ".json", "w") as f:
        json.dump(meta, f, indent=2, default=float)
    return path


def restore_checkpoint(path: str, template: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template`` (shape-checked)."""
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        name = "/".join(re.sub(r"[\[\]'\.]", "", str(x)) for x in p)
        arr = data[name]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {name}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    meta = {}
    meta_path = path + ".json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(jax.tree.structure(template), leaves), meta


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(p for p in os.listdir(directory)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    return os.path.join(directory, ckpts[-1]) if ckpts else None
