"""Tiny, dependency-free stand-in for the slice of ``hypothesis`` the test
suite uses.

The tier-1 container does not ship ``hypothesis``; rather than skipping the
property tests there, this module degrades ``@given`` to a fixed-seed sweep:
each decorated test runs ``min(max_examples, CAP)`` deterministic examples
drawn from the declared strategies with a seed derived from the test name
and example index (stable across processes — ``zlib.crc32``, not ``hash``).

Only the strategies the repo's tests use are provided: ``integers``,
``floats``, ``lists``, ``sampled_from``, ``booleans``. CI installs the real
``hypothesis`` and never imports this module (see the try/except at the top
of each property-test file).
"""
from __future__ import annotations

import random
import zlib
from types import SimpleNamespace

_EXAMPLE_CAP = 8   # fallback keeps tier-1 fast; real hypothesis runs the full budget


class _Strategy:
    def __init__(self, sample_fn):
        self._sample_fn = sample_fn

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def _lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
           **_kw) -> _Strategy:
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(sample)


strategies = SimpleNamespace(integers=_integers, floats=_floats,
                             booleans=_booleans, sampled_from=_sampled_from,
                             lists=_lists)


def given(**strats):
    """Run the test once per deterministic example (fixed-seed sweep).

    The wrapper deliberately takes no parameters and does not set
    ``__wrapped__`` — pytest introspects the signature for fixtures, and the
    strategy-driven parameters must stay invisible to it.
    """
    def deco(fn):
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", _EXAMPLE_CAP),
                    _EXAMPLE_CAP)
            for i in range(n):
                seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}".encode())
                rng = random.Random(seed)
                example = {k: s.sample(rng) for k, s in sorted(strats.items())}
                fn(**example)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = _EXAMPLE_CAP
        return wrapper
    return deco


def settings(max_examples: int = _EXAMPLE_CAP, deadline=None, **_kw):
    """Accepts (and mostly ignores) the knobs the tests pass."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
