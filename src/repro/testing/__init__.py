"""Test-support utilities that ship with the library (not the test tree) so
they are importable anywhere ``repro`` is — most notably the ``hypo``
fallback that lets the property-based tests run without ``hypothesis``."""
from repro.testing.hypo import given, settings, strategies  # noqa: F401
