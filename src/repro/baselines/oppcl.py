"""Opportunistic Collaborative Learning (Lee et al. 2021).

Egocentric cycle per encounter: exchange - train - exchange - aggregate.
Device i sends its model to an encountered peer j; j trains i's model on
j's local data and returns it; i aggregates the returned model with its own.
Vectorized simplification (documented): each device picks its nearest
neighbor as the peer for the step.

Sharded populations: with a ``RingSpec`` the nearest-neighbor search runs
blockwise inside ``shard_map`` — each shard's (pos, area, active, batches)
block streams around the mesh ring, and every local row keeps a running
lexicographic minimum over ``(distance^2, global peer index)`` plus the
winning peer's batch. The lexicographic tie-break makes the result
independent of ring order, so it equals the single-host full-row ``argmin``
(first occurrence) exactly; since the per-row train/aggregate math is
shard-local, the sharded step is bitwise-equal to single host on any mesh.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.baselines.gossip import RingSpec
from repro.core.aggregation import batched_mix
from repro.kernels.encounter_mix import encounter_gate


def _block_d2(pos_r, area_r, act_r, row0, pos_v, area_v, act_v, col0):
    """Squared distances of local rows vs a visiting block, inf where the
    pair fails the shared non-distance gates (``encounter_gate``)."""
    d2, gate = encounter_gate(pos_r, area_r, act_r, row0,
                              pos_v, area_v, act_v, col0)
    return jnp.where(gate, d2, jnp.inf)


def _ring_nearest_peer(pos, area, active, batches, *, radius: float,
                       ring: RingSpec):
    """Cross-shard nearest-encounter search; returns (peer_batches, met)."""
    m_loc = pos.shape[0]
    i = jax.lax.axis_index(ring.axis_name)
    row0 = i * m_loc
    act = (jnp.ones((m_loc,), bool) if active is None else active)
    visiting = (pos, area, act, batches)
    best_d2 = jnp.full((m_loc,), jnp.inf)
    best_g = jnp.full((m_loc,), jnp.iinfo(jnp.int32).max, jnp.int32)
    best_b = batches                         # placeholder rows; met gates use
    for s in range(ring.axis_size):
        col0 = ((i - s) % ring.axis_size) * m_loc
        pos_v, area_v, act_v, batch_v = visiting
        d2 = _block_d2(pos, area, act, row0, pos_v, area_v, act_v, col0)
        d2 = jnp.where(d2 <= radius ** 2, d2, jnp.inf)
        j = jnp.argmin(d2, axis=1)                           # [m_loc]
        cand = jnp.min(d2, axis=1)
        cand_g = (col0 + j).astype(jnp.int32)
        better = (cand < best_d2) | ((cand == best_d2) & (cand_g < best_g))
        best_d2 = jnp.where(better, cand, best_d2)
        best_g = jnp.where(better, cand_g, best_g)
        cand_b = jax.tree.map(lambda l: l[j], batch_v)
        best_b = jax.tree.map(
            lambda n, o: jnp.where(
                better.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            cand_b, best_b)
        if s + 1 < ring.axis_size:
            visiting = jax.tree.map(
                lambda l: jax.lax.ppermute(l, ring.axis_name, ring.perm()),
                visiting)
    met = jnp.isfinite(best_d2).astype(jnp.float32)
    return best_b, met


def oppcl_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
               batches: Any, train_fn: Callable, key, *,
               radius: float = 0.15, gamma: float = 0.5,
               active: Optional[jnp.ndarray] = None, backend: str = "ref",
               ring: Optional[RingSpec] = None, keys=None) -> Any:
    """One OppCL cycle over the population block.

    ``ring``/``keys`` follow the ``gossip_step`` contract (shard-local
    block + streamed neighbor search / externally supplied per-row train
    keys). ``backend`` is accepted for signature uniformity with
    ``gossip_step``; the peer search is D-free, so there is no kernel to
    select.
    """
    m = pos.shape[0]
    if ring is None:
        d2 = _block_d2(pos, area, active, 0, pos, area, active, 0)
        d2 = jnp.where(d2 <= radius ** 2, d2, jnp.inf)
        peer = jnp.argmin(d2, axis=1)                              # [M]
        met = jnp.isfinite(jnp.min(d2, axis=1)).astype(jnp.float32)
        peer_batches = jax.tree.map(lambda l: l[peer], batches)    # j's data
    else:
        peer_batches, met = _ring_nearest_peer(pos, area, active, batches,
                                               radius=radius, ring=ring)

    # peer j trains i's model on j's data (exchange-train), then
    # (exchange back - aggregate)
    if keys is None:
        keys = jax.random.split(key, m)
    trained = jax.vmap(train_fn)(models, peer_batches, keys)
    return batched_mix(models, trained, gamma * met)
