"""Opportunistic Collaborative Learning (Lee et al. 2021).

Egocentric cycle per encounter: exchange - train - exchange - aggregate.
Device i sends its model to an encountered peer j; j trains i's model on
j's local data and returns it; i aggregates the returned model with its own.
Vectorized simplification (documented): each device picks its nearest
neighbor as the peer for the step.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.baselines.gossip import encounter_matrix
from repro.core.aggregation import batched_mix


def oppcl_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
               batches: Any, train_fn: Callable, key, *,
               radius: float = 0.15, gamma: float = 0.5,
               active: Optional[jnp.ndarray] = None) -> Any:
    m = pos.shape[0]
    enc = encounter_matrix(pos, area, radius, active)
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    d2 = jnp.where(enc, d2, jnp.inf)
    peer = jnp.argmin(d2, axis=1)                                  # [M]
    met = jnp.isfinite(jnp.min(d2, axis=1)).astype(jnp.float32)

    # peer j trains i's model on j's data (exchange-train)
    my_model_at_peer = models                                      # i's model ...
    peer_batches = jax.tree.map(lambda l: l[peer], batches)        # ... j's data
    keys = jax.random.split(key, m)
    trained = jax.vmap(train_fn)(my_model_at_peer, peer_batches, keys)
    # (exchange back - aggregate)
    return batched_mix(models, trained, gamma * met)
