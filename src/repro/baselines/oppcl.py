"""Opportunistic Collaborative Learning (Lee et al. 2021).

Egocentric cycle per encounter: exchange - train - exchange - aggregate.
Device i sends its model to an encountered peer j; j trains i's model on
j's local data and returns it; i aggregates the returned model with its own.
Vectorized simplification (documented): each device picks its nearest
neighbor as the peer for the step.

Sharded populations: with a ``RingSpec`` the nearest-neighbor search runs
blockwise inside ``shard_map`` — each shard's (pos, area, active, batches)
block arrives by direct ring shift (``shift_perm``), and every local row
keeps a running lexicographic minimum over ``(distance^2, global peer
index)`` plus the winning peer's batch. The lexicographic tie-break makes
the result independent of ring order, so it equals the single-host
full-row ``argmin`` (first occurrence) exactly; since the per-row
train/aggregate math is shard-local, the sharded step is bitwise-equal to
single host on any mesh. With ``ring.prune`` the search shares gossip's
area-bitmask hop predicate: a pruned hop's block is all-``inf`` distance
(no same-area active pair), so skipping its transfer and its ``argmin``
update leaves ``met`` and every met row's winner unchanged — rows that met
no peer may carry different placeholder batches, but ``gamma * met = 0``
gates them out of the aggregate bitwise.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.baselines.gossip import RingSpec, _ring_need, _ring_shift
from repro.core.aggregation import batched_mix
from repro.kernels.encounter_mix import encounter_gate


def _block_d2(pos_r, area_r, act_r, row0, pos_v, area_v, act_v, col0):
    """Squared distances of local rows vs a visiting block, inf where the
    pair fails the shared non-distance gates (``encounter_gate``)."""
    d2, gate = encounter_gate(pos_r, area_r, act_r, row0,
                              pos_v, area_v, act_v, col0)
    return jnp.where(gate, d2, jnp.inf)


def _ring_nearest_peer(pos, area, active, batches, *, radius: float,
                       ring: RingSpec):
    """Cross-shard nearest-encounter search; returns (peer_batches, met)."""
    m_loc = pos.shape[0]
    n = ring.axis_size
    i = jax.lax.axis_index(ring.axis_name)
    row0 = i * m_loc
    act = (jnp.ones((m_loc,), bool) if active is None else active)
    orig = (pos, area, act, batches)

    def consume(carry, visiting, col0):
        best_d2, best_g, best_b = carry
        pos_v, area_v, act_v, batch_v = visiting
        d2 = _block_d2(pos, area, act, row0, pos_v, area_v, act_v, col0)
        d2 = jnp.where(d2 <= radius ** 2, d2, jnp.inf)
        j = jnp.argmin(d2, axis=1)                           # [m_loc]
        cand = jnp.min(d2, axis=1)
        cand_g = (col0 + j).astype(jnp.int32)
        better = (cand < best_d2) | ((cand == best_d2) & (cand_g < best_g))
        best_d2 = jnp.where(better, cand, best_d2)
        best_g = jnp.where(better, cand_g, best_g)
        cand_b = jax.tree.map(lambda l: l[j], batch_v)
        best_b = jax.tree.map(
            lambda nw, o: jnp.where(
                better.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, o),
            cand_b, best_b)
        return best_d2, best_g, best_b

    carry = (jnp.full((m_loc,), jnp.inf),
             jnp.full((m_loc,), jnp.iinfo(jnp.int32).max, jnp.int32),
             batches)                  # placeholder rows; met gates use
    carry = consume(carry, orig, row0)            # shift 0: local block
    if n > 1:
        need = _ring_need(area, act, ring) if ring.prune else None
        nxt = _ring_shift(orig, 1, ring, need)
        for s in range(1, n):
            blk = nxt
            if s + 1 < n:    # issue the next transfer before consuming
                nxt = _ring_shift(orig, s + 1, ring, need)
            col0 = ((i - s) % n) * m_loc
            if need is None:
                carry = consume(carry, blk, col0)
            else:
                carry = jax.lax.cond(
                    need[s],
                    lambda args, c0=col0: consume(args[0], args[1], c0),
                    lambda args: args[0], (carry, blk))
    best_d2, _, best_b = carry
    met = jnp.isfinite(best_d2).astype(jnp.float32)
    return best_b, met


def oppcl_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
               batches: Any, train_fn: Callable, key, *,
               radius: float = 0.15, gamma: float = 0.5,
               active: Optional[jnp.ndarray] = None, backend: str = "ref",
               ring: Optional[RingSpec] = None, keys=None) -> Any:
    """One OppCL cycle over the population block.

    ``ring``/``keys`` follow the ``gossip_step`` contract (shard-local
    block + streamed neighbor search / externally supplied per-row train
    keys). ``backend`` is accepted for signature uniformity with
    ``gossip_step``; the peer search is D-free, so there is no kernel to
    select.
    """
    m = pos.shape[0]
    if ring is None:
        d2 = _block_d2(pos, area, active, 0, pos, area, active, 0)
        d2 = jnp.where(d2 <= radius ** 2, d2, jnp.inf)
        peer = jnp.argmin(d2, axis=1)                              # [M]
        met = jnp.isfinite(jnp.min(d2, axis=1)).astype(jnp.float32)
        peer_batches = jax.tree.map(lambda l: l[peer], batches)    # j's data
    else:
        peer_batches, met = _ring_nearest_peer(pos, area, active, batches,
                                               radius=radius, ring=ring)

    # peer j trains i's model on j's data (exchange-train), then
    # (exchange back - aggregate)
    if keys is None:
        keys = jax.random.split(key, m)
    trained = jax.vmap(train_fn)(models, peer_batches, keys)
    return batched_mix(models, trained, gamma * met)
