"""Local-only: every device trains on its own data; no communication."""
from __future__ import annotations

from typing import Any, Callable

import jax


def local_step(models: Any, batches: Any, train_fn: Callable, key) -> Any:
    """models: stacked [P, ...]; batches: [P, B, ...]."""
    n = jax.tree.leaves(models)[0].shape[0]
    keys = jax.random.split(key, n)
    return jax.vmap(train_fn)(models, batches, keys)
