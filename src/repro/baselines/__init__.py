"""Baselines the paper compares against (Sec 4).

Federated (server-coordinated, time-coupled):
- ``fedavg``  — McMahan et al. [10]
- ``cfl``     — Clustered FL, Sattler et al. [11] (bipartition on update
                cosine similarity)
- ``fedas``   — personalized FL with shared-backbone alignment, Yang et al.
                [12] (simplified: shared feature extractor aggregated +
                aligned, personal classifier kept local)

Decentralized (device-to-device, space+time-coupled):
- ``gossip``  — Hegedűs et al. [5]: exchange-aggregate-train per encounter
- ``oppcl``   — Lee et al. [6]: exchange-train-exchange-aggregate

- ``local_only`` — no communication.
"""
from repro.baselines.fedavg import fedavg_round  # noqa: F401
from repro.baselines.cfl import CFLState, cfl_round  # noqa: F401
from repro.baselines.fedas import fedas_round  # noqa: F401
from repro.baselines.gossip import gossip_step  # noqa: F401
from repro.baselines.oppcl import oppcl_step  # noqa: F401
from repro.baselines.local_only import local_step  # noqa: F401
