"""FedAvg (McMahan et al. 2017): server round = broadcast, local train,
weighted average by client data size."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import weighted_average


def fedavg_round(global_model: Any, client_batches: Any, client_sizes: jnp.ndarray,
                 train_fn: Callable, key, local_steps: int = 1) -> Any:
    """client_batches: stacked [C, steps?, B, ...] consumed by train_fn.

    train_fn(params, batch, key) -> params; applied ``local_steps`` times.
    """
    n_clients = client_sizes.shape[0]
    bcast = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_clients,) + l.shape), global_model)

    def local(params, batch, k):
        def body(i, p):
            return train_fn(p, batch, jax.random.fold_in(k, i))
        return jax.lax.fori_loop(0, local_steps, body, params)

    keys = jax.random.split(key, n_clients)
    locals_ = jax.vmap(local)(bcast, client_batches, keys)
    return weighted_average(locals_, client_sizes.astype(jnp.float32))
