"""Clustered Federated Learning (Sattler et al. 2019).

Recursive bipartitioning: when the global objective stagnates (mean client
update norm below eps1) but some client still moves (max norm above eps2),
the cluster is split into two groups by the sign structure of pairwise
cosine similarities between client updates; each cluster then runs FedAvg
independently. The cluster bookkeeping runs on host (numpy) between rounds,
as in practical CFL implementations; the training/aggregation math is JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_average


@dataclasses.dataclass
class CFLState:
    clusters: List[np.ndarray]        # list of client-index arrays
    models: List[Any]                 # one model per cluster
    eps1: float = 0.05                # stagnation norm
    eps2: float = 0.4                 # max-client norm to trigger split
    min_cluster: int = 2


def _flat(tree) -> jnp.ndarray:
    return jnp.concatenate([l.reshape(-1) for l in jax.tree.leaves(tree)])


def _bipartition(sim: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split clients into two groups maximizing intra-group cosine sim
    (greedy spectral-sign heuristic on the similarity matrix)."""
    w, v = np.linalg.eigh(sim)
    lead = v[:, -1]
    g1 = np.where(lead >= np.median(lead))[0]
    g2 = np.where(lead < np.median(lead))[0]
    if len(g1) == 0 or len(g2) == 0:  # degenerate; split by half
        order = np.argsort(lead)
        g1, g2 = order[: len(order) // 2], order[len(order) // 2:]
    return g1, g2


def cfl_round(state: CFLState, client_batches: Any, client_sizes: jnp.ndarray,
              train_fn: Callable, key, local_steps: int = 1) -> CFLState:
    """One communication round over all clusters, with split checks."""
    new_clusters: List[np.ndarray] = []
    new_models: List[Any] = []
    for ci, (idx, model) in enumerate(zip(state.clusters, state.models)):
        take = lambda l: l[jnp.asarray(idx)]
        batches_c = jax.tree.map(take, client_batches)
        sizes_c = client_sizes[jnp.asarray(idx)]
        n = len(idx)
        bcast = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), model)

        def local(params, batch, k):
            def body(i, p):
                return train_fn(p, batch, jax.random.fold_in(k, i))
            return jax.lax.fori_loop(0, local_steps, body, params)

        keys = jax.random.split(jax.random.fold_in(key, ci), n)
        locals_ = jax.vmap(local)(bcast, batches_c, keys)
        # client updates
        upd = jax.tree.map(lambda loc, g: loc - g[None], locals_, model)
        flat_upd = jax.vmap(_flat)(upd)                          # [n, D]
        norms = np.asarray(jnp.linalg.norm(flat_upd, axis=1))
        mean_norm = float(jnp.linalg.norm(jnp.mean(flat_upd, axis=0)))
        agg = weighted_average(locals_, sizes_c.astype(jnp.float32))

        do_split = (mean_norm < state.eps1 and norms.max() > state.eps2
                    and n >= 2 * state.min_cluster)
        if do_split:
            fu = np.asarray(flat_upd)
            nrm = np.linalg.norm(fu, axis=1, keepdims=True) + 1e-9
            sim = (fu / nrm) @ (fu / nrm).T
            g1, g2 = _bipartition(sim)
            if len(g1) >= state.min_cluster and len(g2) >= state.min_cluster:
                for g in (g1, g2):
                    sub = jnp.asarray(g)
                    sub_model = weighted_average(
                        jax.tree.map(lambda l: l[sub], locals_),
                        sizes_c[sub].astype(jnp.float32))
                    new_clusters.append(idx[g])
                    new_models.append(sub_model)
                continue
        new_clusters.append(idx)
        new_models.append(agg)
    return dataclasses.replace(state, clusters=new_clusters, models=new_models)


def cfl_client_models(state: CFLState, n_clients: int) -> Any:
    """Stacked [C, ...] view: each client gets its cluster's model."""
    order = np.zeros(n_clients, np.int64)
    for ci, idx in enumerate(state.clusters):
        order[idx] = ci
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *state.models)
    return jax.tree.map(lambda l: l[jnp.asarray(order)], stacked)
