"""FedAS (Yang et al., CVPR 2024) — simplified faithful core.

FedAS bridges inconsistency in personalized FL with two mechanisms:
(1) **federated parameter alignment** — before local training, the client's
    *shared* parameters are re-aligned to the server state so stale personal
    models don't drag the aggregate; personal (classifier) parameters never
    leave the device;
(2) **client-synchronized aggregation weights** — aggregation weighted by
    how in-sync a client's shared update is (we use cosine similarity to the
    mean update as the sync score).

``shared_predicate(path)`` decides which leaves are shared (default:
everything except leaves whose path contains "fc2"/"head" — task classifier).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import weighted_average


def default_shared_predicate(path: str) -> bool:
    return not any(k in path for k in ("fc2", "head"))


def _split(tree: Any, pred: Callable[[str], bool]):
    """Returns masks pytree (1.0 shared / 0.0 personal) matching tree."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def mask_of(path_leaf):
        path, leaf = path_leaf
        name = "/".join(str(p) for p in path)
        return jnp.full_like(leaf, 1.0 if pred(name) else 0.0)

    masks = [mask_of(pl) for pl in flat]
    treedef = jax.tree.structure(tree)
    return jax.tree.unflatten(treedef, masks)


def fedas_round(global_shared: Any, client_models: Any, client_batches: Any,
                client_sizes: jnp.ndarray, train_fn: Callable, key,
                shared_pred: Callable[[str], bool] = default_shared_predicate,
                local_steps: int = 1):
    """Returns (new_global_shared, new_client_models).

    client_models: stacked [C, ...] personalized models (clients keep their
    personal parts across rounds).
    """
    n = client_sizes.shape[0]
    mask = _split(global_shared, shared_pred)

    # (1) alignment: overwrite each client's shared part with the server's
    aligned = jax.tree.map(
        lambda cm, g, m: cm * (1 - m[None]) + jnp.broadcast_to(g[None], cm.shape) * m[None],
        client_models, global_shared, mask)

    def local(params, batch, k):
        def body(i, p):
            return train_fn(p, batch, jax.random.fold_in(k, i))
        return jax.lax.fori_loop(0, local_steps, body, params)

    keys = jax.random.split(key, n)
    trained = jax.vmap(local)(aligned, client_batches, keys)

    # (2) sync-scored aggregation of the shared part
    upd = jax.tree.map(lambda tr, al: (tr - al), trained, aligned)
    flat = jax.vmap(lambda u: jnp.concatenate(
        [l.reshape(-1) for l in jax.tree.leaves(u)]))(upd)       # [C, D]
    mean_u = jnp.mean(flat, axis=0, keepdims=True)
    cos = jnp.sum(flat * mean_u, axis=1) / (
        jnp.linalg.norm(flat, axis=1) * jnp.linalg.norm(mean_u) + 1e-9)
    sync_w = jax.nn.relu(cos) + 1e-3
    weights = client_sizes.astype(jnp.float32) * sync_w
    new_global = weighted_average(trained, weights)
    # personal parts stay local:
    new_global = jax.tree.map(lambda g, old, m: g * m + old * (1 - m),
                              new_global, global_shared, mask)
    return new_global, trained
