"""Gossip Learning (Hegedűs et al. 2019).

Per encounter: exchange-aggregate-train. Mobile devices within
``radius`` of each other in the same area exchange models, average with all
neighbors (masked row-normalized mixing), then train one local step.

The neighbor average is the fused ``encounter_mix`` op
(``repro.kernels.encounter_mix``): models flatten once to an [M, D] matrix
and one pass computes the distance-tested, row-normalized mix — the former
dense path (``encounter_matrix`` + per-leaf ``masked_group_mean``) survives
below only as the benchmark baseline it was replaced by.

Sharded populations: with a ``RingSpec`` the step runs inside ``shard_map``
over the mesh mule axis. Each shard holds a block of the population; hop
``s`` ``ppermute``s the original (pos, area, active, flattened models)
block straight from shard ``(i - s) % n`` (``shift_perm``), one
``encounter_block`` partial accumulated per hop, and the row normalization
happens once at the end — so no shard ever sees the full [M, M] matrix
either. Because the hops are independent shifts of the same block (not a
chained forward), the ring is locality-aware: each shard publishes a
32- or 64-bit area-set summary (one tiny psum per exchange), and every remote
hop whose source/destination area sets provably cannot intersect skips
both its payload ``ppermute`` and its block compute under ``lax.cond`` —
a pruned hop would have contributed exactly zero, so the pruned and
unpruned rings agree bitwise. The next hop's permute is issued before the
in-flight block is consumed (double buffering), and ``backend="pallas"``
routes each hop's block math through the per-hop tile kernel
(``encounter_block_hop``). A 1-shard ring is exactly the single-host
*ref* call, so the distributed engine is bitwise-equal to single host on
a 1-device mesh under the default ``enc_backend="ref"``.

Mules should be ordered by spatial bucket for the pruning to bite — see
``repro.core.distributed.bucket_mule_order`` (build-time ordering) and
``migrate_mules`` (the mid-run re-bucketing primitive).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import batched_mix, masked_group_mean
from repro.kernels.encounter_mix import (encounter_block,
                                         encounter_block_hop, encounter_mix,
                                         normalize_mix)

N_AREA_BITS = 32


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Mesh ring for cross-shard encounter search.

    ``axis_name`` is the shard_map mule axis; ``axis_size`` its static size
    (the ring unrolls one ``ppermute`` hop per shard). ``prune`` enables
    the area-bitmask hop pruning — exact, so it is on by default; the
    benchmarks flip it off to measure the dense ring. ``n_bits`` is the
    area-summary mask width: area ids fold with ``% n_bits``, so runs with
    more than ``n_bits`` distinct areas alias bits and lose pruning power
    (never soundness) — the drivers widen to 64 automatically when area
    ids overflow 32 (``DistributedConfig.ring_bits``).
    """
    axis_name: str
    axis_size: int
    prune: bool = True
    n_bits: int = N_AREA_BITS

    def perm(self) -> List[Tuple[int, int]]:
        return [(s, (s + 1) % self.axis_size) for s in range(self.axis_size)]

    def shift_perm(self, s: int) -> List[Tuple[int, int]]:
        """Permutation delivering shard j's block to shard (j + s) % n —
        i.e. after one ppermute every shard i holds shard (i - s) % n."""
        return [(j, (j + s) % self.axis_size)
                for j in range(self.axis_size)]


def area_bits(area: jnp.ndarray, active: Optional[jnp.ndarray] = None,
              n_bits: int = N_AREA_BITS) -> jnp.ndarray:
    """[m] int areas (+ optional [m] active mask) -> [n_bits] bool summary.

    Bit ``b`` is set iff some active row has ``area % n_bits == b``. Hash
    collisions (areas ``n_bits`` apart) can only *add* bits, so a predicate
    built on these summaries may keep a skippable hop but can never prune a
    hop whose blocks truly share an area.
    """
    hit = (area[:, None] % n_bits) == jnp.arange(n_bits)[None, :]
    if active is not None:
        hit = hit & active[:, None]
    return jnp.any(hit, axis=0)


def hops_needed(all_bits: jnp.ndarray) -> jnp.ndarray:
    """[n_shards, n_bits] per-shard area summaries -> [n_shards] bool.

    Entry ``s`` answers: does *any* shard's area set intersect the area set
    of its shift-``s`` ring source ``(i - s) % n``? (``roll(+s)`` aligns
    each row ``i`` with row ``(i - s) % n``.) Entry 0 — the shard-local
    block — is True whenever any shard has an active mule.
    """
    n = all_bits.shape[0]
    return jnp.stack([jnp.any(all_bits & jnp.roll(all_bits, s, axis=0))
                      for s in range(n)])


def ring_hop_mask(area: jnp.ndarray, active: Optional[jnp.ndarray],
                  n_shards: int,
                  n_bits: int = N_AREA_BITS) -> jnp.ndarray:
    """Host-side mirror of the in-ring pruning predicate.

    Splits the global ``area``/``active`` rows into ``n_shards`` equal
    blocks (the shard layout) and returns the [n_shards] bool hop mask the
    pruned ring computes — shared by the benchmark telemetry and the
    property tests so both exercise the exact predicate the ring runs.
    """
    m_loc = area.shape[0] // n_shards
    blocks = []
    for k in range(n_shards):
        sl = slice(k * m_loc, (k + 1) * m_loc)
        blocks.append(area_bits(jnp.asarray(area)[sl],
                                None if active is None
                                else jnp.asarray(active)[sl],
                                n_bits=n_bits))
    return hops_needed(jnp.stack(blocks))


def area_bit_collision_rate(area, n_bits: int = N_AREA_BITS) -> float:
    """Fraction of distinct area ids that share their summary bit with
    another distinct id under the ``% n_bits`` fold.

    0.0 means the bitmask separates every area (pruning at full power);
    anything above it measures how much the fold blunts the predicate —
    aliased areas can only *retain* hops, never prune a needed one, so
    this is a telemetry number, not a soundness concern. Recorded per run
    in the encounter-bench ring telemetry.
    """
    u = np.unique(np.asarray(area))
    if u.size == 0:
        return 0.0
    bits = u % n_bits
    _, counts = np.unique(bits, return_counts=True)
    collided = int(counts[counts > 1].sum())
    return float(collided) / float(u.size)


def _ring_need(area, act, ring: RingSpec) -> jnp.ndarray:
    """Replicated [axis_size] hop mask, computed in-ring via one psum.

    The per-shard bitmask is scattered into an [n, n_bits] table with a
    ``psum`` (rather than ``all_gather``) so the result is known-replicated
    and may gate a ``lax.cond`` whose true branch contains a collective.
    """
    n = ring.axis_size
    i = jax.lax.axis_index(ring.axis_name)
    bits = area_bits(area, act, n_bits=ring.n_bits)
    mine = ((jnp.arange(n) == i).astype(jnp.float32)[:, None]
            * bits.astype(jnp.float32)[None, :])
    all_bits = jax.lax.psum(mine, ring.axis_name) > 0
    return hops_needed(all_bits)


def _ring_shift(orig, s: int, ring: RingSpec, need):
    """ppermute ``orig`` around the ring by shift ``s``; when hop ``s`` is
    pruned the transfer itself is skipped (the untouched tuple flows into
    a consume that the same predicate also skips)."""
    def send(o):
        return jax.tree.map(
            lambda l: jax.lax.ppermute(l, ring.axis_name,
                                       ring.shift_perm(s)), o)
    if need is None:
        return send(orig)
    return jax.lax.cond(need[s], send, lambda o: o, orig)


def flatten_population(models: Any) -> Tuple[jnp.ndarray, Any]:
    """Stacked pytree [M, ...] -> (f32 [M, D] matrix, unflatten spec)."""
    leaves, treedef = jax.tree.flatten(models)
    m = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
    return flat, (treedef, shapes, [l.dtype for l in leaves])


def unflatten_population(flat: jnp.ndarray, spec: Any) -> Any:
    treedef, shapes, dtypes = spec
    outs, off = [], 0
    for s, dt in zip(shapes, dtypes):
        n = int(np.prod(s)) if s else 1
        outs.append(flat[:, off:off + n]
                    .reshape((flat.shape[0],) + s).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, outs)


def encounter_matrix(pos: jnp.ndarray, area: jnp.ndarray, radius: float,
                     active: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """pos [M,2], area [M] -> symmetric bool [M,M] (no self).

    The retired dense path (kept as the ``run_encounter_bench`` baseline
    and for O(M^2)-tolerant callers). ``active`` ([M] bool, optional) drops
    switched-off mules from both sides of every encounter — a sleeping
    device neither initiates nor serves as a peer.
    """
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    same_area = area[:, None] == area[None, :]
    enc = (d2 <= radius ** 2) & same_area
    if active is not None:
        enc = enc & active[:, None] & active[None, :]
    return enc & ~jnp.eye(pos.shape[0], dtype=bool)


def ring_encounter_mix(pos: jnp.ndarray, area: jnp.ndarray,
                       active: Optional[jnp.ndarray], flat: jnp.ndarray, *,
                       radius: float, ring: RingSpec,
                       backend: str = "ref",
                       block_m: Optional[int] = None,
                       block_d: Optional[int] = None):
    """Blockwise ``encounter_mix`` across the mesh ring (inside shard_map).

    All arguments are this shard's block ([m_loc, ...]). Hop ``s`` matches
    the local rows against the block ``shift_perm(s)``-permuted straight
    from shard ``(i - s) % n`` — the same per-hop partials (in the same
    accumulation order) as a chained single-shift ring, but with hops
    independent of each other, which buys three things: with ``ring.prune``
    each remote hop's payload permute *and* block compute sit under a
    ``lax.cond`` keyed on the per-shard area bitmasks; hop ``s+1``'s
    permute is issued before hop ``s``'s block is consumed (double
    buffering, so the transfer overlaps the compute); and ``backend``
    selects the per-hop block math (``encounter_block_hop`` — ref einsum
    or the tiled Pallas hop kernel). Returns the local rows'
    (mix [m_loc, D], mass [m_loc]).
    """
    m_loc = flat.shape[0]
    n = ring.axis_size
    i = jax.lax.axis_index(ring.axis_name)
    row0 = i * m_loc
    act = (jnp.ones((m_loc,), bool) if active is None else active)
    orig = (pos, area, act, flat)

    def hop(visiting, col0):
        pos_v, area_v, act_v, flat_v = visiting
        return encounter_block_hop(pos, area, act, row0, pos_v, area_v,
                                   act_v, col0, flat_v, radius,
                                   backend=backend, block_m=block_m,
                                   block_d=block_d)

    acc, mass = hop(orig, row0)                    # shift 0: local block
    if n > 1:
        need = _ring_need(area, act, ring) if ring.prune else None

        def consume(blk, s):
            col0 = ((i - s) % n) * m_loc
            if need is None:
                return hop(blk, col0)
            return jax.lax.cond(
                need[s], lambda b: hop(b, col0),
                lambda b: (jnp.zeros_like(acc), jnp.zeros_like(mass)), blk)

        nxt = _ring_shift(orig, 1, ring, need)
        for s in range(1, n):
            blk = nxt
            if s + 1 < n:       # issue the next transfer before consuming
                nxt = _ring_shift(orig, s + 1, ring, need)
            p_acc, p_mass = consume(blk, s)
            acc = acc + p_acc
            mass = mass + p_mass
    return normalize_mix(acc, mass), mass


def gossip_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
                batches: Any, train_fn: Callable, key, *,
                radius: float = 0.15, gamma: float = 0.5,
                active: Optional[jnp.ndarray] = None, backend: str = "ref",
                ring: Optional[RingSpec] = None, keys=None) -> Any:
    """One gossip exchange-aggregate-train step over the population block.

    ``ring=None`` runs single-host over the full population (``backend``
    selects ref vs the tiled Pallas kernel); with a ``RingSpec`` the step
    is the shard-local block of a shard_map'd population and neighbors
    stream around the mesh ring. ``keys`` overrides the per-device training
    keys ([M, 2]) — the distributed engine passes the global-split local
    slice so sharded draws match single host row for row.
    """
    flat, spec = flatten_population(models)
    if ring is None:
        mixed, mass = encounter_mix(pos, area, active, flat, radius=radius,
                                    backend=backend)
    else:
        mixed, mass = ring_encounter_mix(pos, area, active, flat,
                                         radius=radius, ring=ring,
                                         backend=backend)
    neigh_mean = unflatten_population(mixed, spec)
    met = (mass > 0).astype(jnp.float32)
    models = batched_mix(models, neigh_mean, gamma * met)           # aggregate
    if keys is None:
        keys = jax.random.split(key, mass.shape[0])
    trained = jax.vmap(train_fn)(models, batches, keys)             # train
    return batched_mix(models, trained, met)                        # only on encounter


def gossip_step_dense(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
                      batches: Any, train_fn: Callable, key, *,
                      radius: float = 0.15, gamma: float = 0.5,
                      active: Optional[jnp.ndarray] = None) -> Any:
    """The retired dense gossip step: [M, M] matrix + per-leaf group mean.

    Benchmark baseline only (``benchmarks/engine_micro.run_encounter_bench``
    times it against the fused path); note it normalizes the encounter
    matrix *before* the per-leaf matmuls, so it differs from ``gossip_step``
    in float rounding, not semantics.
    """
    enc = encounter_matrix(pos, area, radius, active).astype(jnp.float32)
    neigh_mean, mass = masked_group_mean(models, enc)
    met = (mass > 0).astype(jnp.float32)
    models = batched_mix(models, neigh_mean, gamma * met)
    keys = jax.random.split(key, mass.shape[0])
    trained = jax.vmap(train_fn)(models, batches, keys)
    return batched_mix(models, trained, met)
