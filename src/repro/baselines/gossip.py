"""Gossip Learning (Hegedűs et al. 2019).

Per encounter: exchange-aggregate-train. Mobile devices within
``radius`` of each other in the same area exchange models, average with all
neighbors (masked row-normalized mixing), then train one local step.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import batched_mix, masked_group_mean


def encounter_matrix(pos: jnp.ndarray, area: jnp.ndarray, radius: float,
                     active: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """pos [M,2], area [M] -> symmetric bool [M,M] (no self).

    ``active`` ([M] bool, optional) drops switched-off mules from both
    sides of every encounter — a sleeping device neither initiates nor
    serves as a peer.
    """
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    same_area = area[:, None] == area[None, :]
    enc = (d2 <= radius ** 2) & same_area
    if active is not None:
        enc = enc & active[:, None] & active[None, :]
    return enc & ~jnp.eye(pos.shape[0], dtype=bool)


def gossip_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
                batches: Any, train_fn: Callable, key, *,
                radius: float = 0.15, gamma: float = 0.5,
                active: Optional[jnp.ndarray] = None) -> Any:
    enc = encounter_matrix(pos, area, radius,
                           active).astype(jnp.float32)              # [M, M]
    neigh_mean, mass = masked_group_mean(models, enc)
    met = (mass > 0).astype(jnp.float32)
    models = batched_mix(models, neigh_mean, gamma * met)           # aggregate
    n = mass.shape[0]
    keys = jax.random.split(key, n)
    trained = jax.vmap(train_fn)(models, batches, keys)             # train
    return batched_mix(models, trained, met)                        # only on encounter
