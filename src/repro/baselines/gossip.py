"""Gossip Learning (Hegedűs et al. 2019).

Per encounter: exchange-aggregate-train. Mobile devices within
``radius`` of each other in the same area exchange models, average with all
neighbors (masked row-normalized mixing), then train one local step.

The neighbor average is the fused ``encounter_mix`` op
(``repro.kernels.encounter_mix``): models flatten once to an [M, D] matrix
and one pass computes the distance-tested, row-normalized mix — the former
dense path (``encounter_matrix`` + per-leaf ``masked_group_mean``) survives
below only as the benchmark baseline it was replaced by.

Sharded populations: with a ``RingSpec`` the step runs inside ``shard_map``
over the mesh mule axis. Each shard holds a block of the population; the
blocks of (pos, area, active, flattened models) stream around the ring by
``ppermute``, one ``encounter_block`` partial accumulated per hop, and the
row normalization happens once at the end — so no shard ever sees the full
[M, M] matrix either. A 1-shard ring is exactly the single-host *ref* call,
so the distributed engine is bitwise-equal to single host on a 1-device
mesh under the default ``enc_backend="ref"`` (the ring has no Pallas
lowering; against a single-host Pallas run, agreement is to the kernel's
pinned tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import batched_mix, masked_group_mean
from repro.kernels.encounter_mix import (encounter_block, encounter_mix,
                                         normalize_mix)


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Mesh ring for cross-shard encounter search.

    ``axis_name`` is the shard_map mule axis; ``axis_size`` its static size
    (the ring unrolls one ``ppermute`` hop per shard).
    """
    axis_name: str
    axis_size: int

    def perm(self) -> List[Tuple[int, int]]:
        return [(s, (s + 1) % self.axis_size) for s in range(self.axis_size)]


def flatten_population(models: Any) -> Tuple[jnp.ndarray, Any]:
    """Stacked pytree [M, ...] -> (f32 [M, D] matrix, unflatten spec)."""
    leaves, treedef = jax.tree.flatten(models)
    m = leaves[0].shape[0]
    shapes = [l.shape[1:] for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
    return flat, (treedef, shapes, [l.dtype for l in leaves])


def unflatten_population(flat: jnp.ndarray, spec: Any) -> Any:
    treedef, shapes, dtypes = spec
    outs, off = [], 0
    for s, dt in zip(shapes, dtypes):
        n = int(np.prod(s)) if s else 1
        outs.append(flat[:, off:off + n]
                    .reshape((flat.shape[0],) + s).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, outs)


def encounter_matrix(pos: jnp.ndarray, area: jnp.ndarray, radius: float,
                     active: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """pos [M,2], area [M] -> symmetric bool [M,M] (no self).

    The retired dense path (kept as the ``run_encounter_bench`` baseline
    and for O(M^2)-tolerant callers). ``active`` ([M] bool, optional) drops
    switched-off mules from both sides of every encounter — a sleeping
    device neither initiates nor serves as a peer.
    """
    d2 = jnp.sum((pos[:, None] - pos[None, :]) ** 2, axis=-1)
    same_area = area[:, None] == area[None, :]
    enc = (d2 <= radius ** 2) & same_area
    if active is not None:
        enc = enc & active[:, None] & active[None, :]
    return enc & ~jnp.eye(pos.shape[0], dtype=bool)


def ring_encounter_mix(pos: jnp.ndarray, area: jnp.ndarray,
                       active: Optional[jnp.ndarray], flat: jnp.ndarray, *,
                       radius: float, ring: RingSpec):
    """Blockwise ``encounter_mix`` across the mesh ring (inside shard_map).

    All arguments are this shard's block ([m_loc, ...]). One hop per shard:
    the visiting (pos, area, active, weights) block is matched against the
    local rows (``encounter_block``), then permuted onward. Returns the
    local rows' (mix [m_loc, D], mass [m_loc]).
    """
    m_loc = flat.shape[0]
    i = jax.lax.axis_index(ring.axis_name)
    row0 = i * m_loc
    act = (jnp.ones((m_loc,), bool) if active is None else active)
    visiting = (pos, area, act, flat)
    acc = jnp.zeros_like(flat, jnp.float32)
    mass = jnp.zeros((m_loc,), jnp.float32)
    for s in range(ring.axis_size):
        col0 = ((i - s) % ring.axis_size) * m_loc
        pos_v, area_v, act_v, flat_v = visiting
        p_acc, p_mass = encounter_block(pos, area, act, row0,
                                        pos_v, area_v, act_v, col0,
                                        flat_v, radius)
        acc = acc + p_acc
        mass = mass + p_mass
        if s + 1 < ring.axis_size:
            visiting = jax.tree.map(
                lambda l: jax.lax.ppermute(l, ring.axis_name, ring.perm()),
                visiting)
    return normalize_mix(acc, mass), mass


def gossip_step(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
                batches: Any, train_fn: Callable, key, *,
                radius: float = 0.15, gamma: float = 0.5,
                active: Optional[jnp.ndarray] = None, backend: str = "ref",
                ring: Optional[RingSpec] = None, keys=None) -> Any:
    """One gossip exchange-aggregate-train step over the population block.

    ``ring=None`` runs single-host over the full population (``backend``
    selects ref vs the tiled Pallas kernel); with a ``RingSpec`` the step
    is the shard-local block of a shard_map'd population and neighbors
    stream around the mesh ring. ``keys`` overrides the per-device training
    keys ([M, 2]) — the distributed engine passes the global-split local
    slice so sharded draws match single host row for row.
    """
    flat, spec = flatten_population(models)
    if ring is None:
        mixed, mass = encounter_mix(pos, area, active, flat, radius=radius,
                                    backend=backend)
    else:
        mixed, mass = ring_encounter_mix(pos, area, active, flat,
                                         radius=radius, ring=ring)
    neigh_mean = unflatten_population(mixed, spec)
    met = (mass > 0).astype(jnp.float32)
    models = batched_mix(models, neigh_mean, gamma * met)           # aggregate
    if keys is None:
        keys = jax.random.split(key, mass.shape[0])
    trained = jax.vmap(train_fn)(models, batches, keys)             # train
    return batched_mix(models, trained, met)                        # only on encounter


def gossip_step_dense(models: Any, pos: jnp.ndarray, area: jnp.ndarray,
                      batches: Any, train_fn: Callable, key, *,
                      radius: float = 0.15, gamma: float = 0.5,
                      active: Optional[jnp.ndarray] = None) -> Any:
    """The retired dense gossip step: [M, M] matrix + per-leaf group mean.

    Benchmark baseline only (``benchmarks/engine_micro.run_encounter_bench``
    times it against the fused path); note it normalizes the encounter
    matrix *before* the per-leaf matmuls, so it differs from ``gossip_step``
    in float rounding, not semantics.
    """
    enc = encounter_matrix(pos, area, radius, active).astype(jnp.float32)
    neigh_mean, mass = masked_group_mean(models, enc)
    met = (mass > 0).astype(jnp.float32)
    models = batched_mix(models, neigh_mean, gamma * met)
    keys = jax.random.split(key, mass.shape[0])
    trained = jax.vmap(train_fn)(models, batches, keys)
    return batched_mix(models, trained, met)
