from repro.mobility.patterns import (  # noqa: F401
    commuter_trace, duty_cycle_mask, event_crowd_trace, flash_churn_mask,
    markov_churn_mask, multi_area_trace, shift_worker_trace)
from repro.mobility.random_walk import (  # noqa: F401
    MobilityConfig, init_mobility, mobility_step, simulate_trajectories, space_of)
from repro.mobility.streaming import (  # noqa: F401
    CommuterStream, CompactColocation, commuter_stream, compact_colocation,
    materialize_generator, reorder_generator_arrays)
from repro.mobility.trace import (  # noqa: F401
    area_over_time, dwell_exchange_flags, synth_foursquare_trace,
    trace_to_colocation, trace_to_colocation_loop)
