from repro.mobility.random_walk import (  # noqa: F401
    MobilityConfig, init_mobility, mobility_step, simulate_trajectories, space_of)
from repro.mobility.trace import synth_foursquare_trace, trace_to_colocation  # noqa: F401
