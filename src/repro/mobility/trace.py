"""Trace-driven mobility: synthetic Foursquare-like visit logs.

The paper's '4Q' condition replays real Foursquare check-ins (user, place,
enter-time, dwell). That dataset is not available offline; this generator
reproduces the properties the paper relies on:

- **subgroup structure** (the ICA clusters of Fig. 3): each user belongs to a
  latent affinity group that concentrates its visits on a subset of places;
- **sparsity**: many users appear briefly and then disappear (heavy-tailed
  participation), which the paper notes makes 4Q slightly harder than the
  dense simulated patterns;
- **no detailed movement** between visits — only (user, place, t_in, t_out),
  so only ML Mule (not gossip-style D2D) can replay it, as in the paper.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def synth_foursquare_trace(seed: int, n_users: int = 40, n_places: int = 8,
                           n_steps: int = 2000, n_groups: int = 2,
                           sparsity: float = 0.5) -> np.ndarray:
    """Returns visits array [n_visits, 4]: (user, place, t_in, t_out).

    Users in group g prefer places assigned to group g (zipf-weighted);
    a `sparsity` fraction of users are transient (few visits).
    """
    rng = np.random.default_rng(seed)
    group_of = rng.integers(0, n_groups, size=n_users)
    place_group = np.arange(n_places) % n_groups
    transient = rng.random(n_users) < sparsity

    visits: List[Tuple[int, int, int, int]] = []
    for u in range(n_users):
        n_visits = rng.integers(2, 6) if transient[u] else rng.integers(15, 40)
        # place preference: own-group places get 10x weight, zipf within group
        w = np.where(place_group == group_of[u], 10.0, 0.2)
        w = w * (1.0 / (1.0 + np.arange(n_places) % (n_places // n_groups)))
        w = w / w.sum()
        t = int(rng.integers(0, max(n_steps // 8, 1)))
        for _ in range(n_visits):
            place = int(rng.choice(n_places, p=w))
            dwell = int(rng.integers(6, 40))
            if t + dwell >= n_steps:
                break
            visits.append((u, place, t, t + dwell))
            t += dwell + int(rng.integers(
                5, max(n_steps // max(n_visits, 1), 1) + 5))
    arr = np.array(sorted(visits, key=lambda v: v[2]), dtype=np.int64)
    return arr


def trace_to_colocation(visits: np.ndarray, n_users: int, n_steps: int,
                        exchange_steps=3) -> np.ndarray:
    """Expand visits into per-step arrays — fully vectorized.

    Returns (fixed_id [T, M] int32 with -1 when not co-located,
             exchange [T, M] bool — True every `exchange_steps`-th
             consecutive step of a visit).

    ``exchange_steps`` may also be an int array indexed by place id —
    heterogeneous exchange tempos per space (a kiosk that completes a
    hand-off in 1 step next to a gallery that needs 8): each dwell counts
    against the cadence of the space it happens in.

    Per-visit fill uses one flat scatter (visits stay in t_in order, so a
    later visit overwrites an overlapping earlier one, like the reference
    loop's slice assignment); dwell counters come from a running-maximum of
    run-start indices instead of a per-step loop, so cost is O(T·M) numpy
    ops, not T Python iterations. ``trace_to_colocation_loop`` is the
    reference implementation tests compare against.
    """
    fixed_id = -np.ones((n_steps, n_users), np.int32)
    if len(visits):
        u, place, t_in, t_out = (np.asarray(visits[:, i]) for i in range(4))
        t_in = np.clip(t_in, 0, n_steps)
        t_out = np.clip(t_out, 0, n_steps)
        lens = np.maximum(t_out - t_in, 0)
        # concatenated aranges: [t_in0..t_out0), [t_in1..t_out1), ...
        offs = np.arange(lens.sum()) - np.repeat(np.cumsum(lens) - lens, lens)
        rows = np.repeat(t_in, lens) + offs
        fixed_id[rows, np.repeat(u, lens)] = np.repeat(place, lens)

    return fixed_id, dwell_exchange_flags(fixed_id, exchange_steps)


def dwell_exchange_flags(fixed_id: np.ndarray, exchange_steps=3) -> np.ndarray:
    """Completed-exchange flags from a filled ``[T, M]`` co-location grid.

    A visit completes an exchange on every ``exchange_steps``-th
    consecutive dwell step; ``exchange_steps`` may be a per-place array
    (heterogeneous space tempos). Factored out of ``trace_to_colocation``
    so the scenario registry can re-derive exchange schedules under a
    declared set of ``SpaceSpec`` tempos.
    """
    n_steps, n_users = fixed_id.shape
    present = fixed_id >= 0
    prev = np.vstack([-np.ones((1, n_users), np.int32), fixed_id[:-1]])
    run_start = present & ((fixed_id != prev) | (prev < 0))
    t_grid = np.arange(n_steps, dtype=np.int64)[:, None]
    start_t = np.where(run_start, t_grid, -1)
    last_start = np.maximum.accumulate(start_t, axis=0)
    dwell = np.where(present, t_grid - last_start + 1, 0)
    steps = _cadence_of(fixed_id, exchange_steps)
    return present & (dwell % steps == 0)


def area_over_time(fixed_id: np.ndarray, init_area,
                   places_per_area: int = 4) -> np.ndarray:
    """Per-step home-area trace ``[T, M]`` from a co-location grid.

    A mule's area is the area of the last place it visited (``place //
    places_per_area``) — corridor steps (``fixed_id == -1``) keep the area
    of the previous visit, and steps before any visit fall back to
    ``init_area``. This is the migratory-scenario companion to
    ``dwell_exchange_flags``: it turns the same grid into the time-varying
    ``"area"`` column the ring's mid-run re-bucketing triggers on.
    """
    fid = np.asarray(fixed_id)
    n_steps, n_users = fid.shape
    present = fid >= 0
    t_grid = np.arange(n_steps, dtype=np.int64)[:, None]
    last_t = np.maximum.accumulate(np.where(present, t_grid, -1), axis=0)
    seen = last_t >= 0
    last_place = np.take_along_axis(fid, np.maximum(last_t, 0).astype(np.intp),
                                    axis=0)
    init = np.broadcast_to(np.asarray(init_area), (n_users,))
    return np.where(seen, last_place // places_per_area,
                    init[None, :]).astype(np.int32)


def _cadence_of(fixed_id: np.ndarray, exchange_steps) -> np.ndarray:
    """Per-cell exchange cadence: scalar, or looked up by space id.

    Only the -1 corridor sentinel is clamped; a place id past the end of
    the per-place array is a misconfiguration (e.g. a 12-place trace with
    an 8-space cadence array) and raises rather than silently reusing the
    last entry.
    """
    if np.ndim(exchange_steps) == 0:
        return np.asarray(exchange_steps, np.int64)
    per_place = np.asarray(exchange_steps, np.int64)
    top = int(fixed_id.max(initial=-1))
    if top >= len(per_place):
        raise ValueError(
            f"place id {top} has no cadence: exchange_steps covers only "
            f"{len(per_place)} places")
    return per_place[np.maximum(fixed_id, 0)]


def trace_to_colocation_loop(visits: np.ndarray, n_users: int, n_steps: int,
                             exchange_steps=3) -> np.ndarray:
    """Reference per-step-loop implementation of ``trace_to_colocation``
    (kept for parity tests; O(T·M) Python iterations)."""
    fixed_id = -np.ones((n_steps, n_users), np.int32)
    for u, place, t_in, t_out in visits:
        fixed_id[t_in:t_out, u] = place
    dwell = np.zeros((n_users,), np.int64)
    exchange = np.zeros((n_steps, n_users), bool)
    prev = -np.ones((n_users,), np.int32)
    for t in range(n_steps):
        same = (fixed_id[t] == prev) & (fixed_id[t] >= 0)
        dwell = np.where(same, dwell + 1, np.where(fixed_id[t] >= 0, 1, 0))
        steps = _cadence_of(fixed_id[t], exchange_steps)
        exchange[t] = (dwell > 0) & (dwell % steps == 0)
        prev = fixed_id[t]
    return fixed_id, exchange
