"""Structured mobility patterns beyond the random walk and the sparse
Foursquare-style trace: visit-log generators for three scenario families the
paper's framing (opportunistic encounters with fixed smart spaces) suggests
but does not simulate.

All generators return the same visit format as ``synth_foursquare_trace`` —
``[n_visits, 4] int64`` rows of ``(user, place, t_in, t_out)`` sorted by
``t_in`` — so ``trace_to_colocation`` expands any of them into the ``[T, M]``
tensors the scan engine consumes.

- ``commuter_trace``     — home/work oscillation on a daily period: long
  dwells at two anchor places per user, commute gaps in between. Dense,
  highly periodic co-location (the easiest condition for ML Mule).
- ``shift_worker_trace`` — crews partition the day into shifts; each crew
  occupies its workplace only during its window and rotates workplaces
  daily, so snapshots hop between places through shift hand-offs.
- ``event_crowd_trace``  — sparse background visits plus scheduled events
  that pull a large user fraction into one venue simultaneously: bursts of
  many concurrent deliveries stress the freshness filter and aggregation.
"""
from __future__ import annotations

import numpy as np


def _sorted_visits(visits) -> np.ndarray:
    if not visits:
        return np.zeros((0, 4), np.int64)
    arr = np.array(visits, np.int64)
    return arr[np.argsort(arr[:, 2], kind="stable")]


def commuter_trace(seed: int, n_users: int = 20, n_places: int = 8,
                   n_steps: int = 2000, period: int = 200,
                   work_frac: float = 0.45, commute: int = 5,
                   jitter: int = 8) -> np.ndarray:
    """Daily home->work->home cycle per user.

    Each user gets a home and a distinct work place; every `period` steps it
    dwells at home, commutes (`commute` steps off-grid), works for
    ``work_frac * period`` steps (start jittered per user/day), and returns
    home. Produces long dwells, so nearly every visit completes exchanges.
    """
    rng = np.random.default_rng(seed)
    home = rng.integers(0, n_places, n_users)
    work = (home + rng.integers(1, n_places, n_users)) % n_places
    work_len = max(int(work_frac * period), 1)
    visits = []
    for u in range(n_users):
        for day in range(max(n_steps // period, 1)):
            base = day * period
            w0 = base + commute + int(rng.integers(0, jitter + 1))
            w1 = w0 + work_len
            h1 = min(base + period, n_steps)
            if base < w0 - commute:
                visits.append((u, home[u], base, min(w0 - commute, n_steps)))
            if w0 < n_steps:
                visits.append((u, work[u], w0, min(w1, n_steps)))
            if w1 + commute < h1:
                visits.append((u, home[u], w1 + commute, h1))
    return _sorted_visits(visits)


def shift_worker_trace(seed: int, n_users: int = 24, n_places: int = 8,
                       n_steps: int = 2000, n_shifts: int = 3,
                       period: int = 240, jitter: int = 6) -> np.ndarray:
    """Round-the-clock crews: user u works shift ``u % n_shifts``.

    A day of `period` steps splits into `n_shifts` equal windows; crew s is
    at its workplace only during window s and rotates workplace daily
    (``(crew_base + day) % n_places``), so fixed devices see a fresh crew
    every window and models relay across places through the rotation.
    """
    rng = np.random.default_rng(seed)
    shift_of = np.arange(n_users) % n_shifts
    crew_base = rng.integers(0, n_places, n_shifts)
    win = period // n_shifts
    visits = []
    for u in range(n_users):
        s = shift_of[u]
        for day in range(max(n_steps // period, 1)):
            t0 = day * period + s * win + int(rng.integers(0, jitter + 1))
            t1 = min(day * period + (s + 1) * win, n_steps)
            place = (crew_base[s] + day) % n_places
            if t0 < t1:
                visits.append((u, place, t0, t1))
    return _sorted_visits(visits)


def event_crowd_trace(seed: int, n_users: int = 30, n_places: int = 8,
                      n_steps: int = 2000, n_events: int = 6,
                      event_len: int = 60, attend: float = 0.7,
                      background_visits: int = 3) -> np.ndarray:
    """Sparse background check-ins punctuated by mass events.

    Events are evenly spaced (start jittered); each picks one venue and an
    ``attend`` fraction of users who all dwell there for ``event_len`` steps
    — many simultaneous deliveries to a single fixed device.
    """
    rng = np.random.default_rng(seed)
    visits = []
    for u in range(n_users):                       # thin background traffic
        for _ in range(int(rng.integers(1, background_visits + 1))):
            t0 = int(rng.integers(0, max(n_steps - 10, 1)))
            dwell = int(rng.integers(4, 20))
            visits.append((u, int(rng.integers(0, n_places)), t0,
                           min(t0 + dwell, n_steps)))
    gap = max(n_steps // max(n_events, 1), event_len + 1)
    for e in range(n_events):
        t0 = min(e * gap + int(rng.integers(0, max(gap - event_len, 1))),
                 max(n_steps - event_len, 0))
        venue = int(rng.integers(0, n_places))
        goers = rng.random(n_users) < attend
        for u in np.nonzero(goers)[0]:
            off = int(rng.integers(0, 5))          # staggered arrivals
            visits.append((int(u), venue, t0 + off,
                           min(t0 + event_len, n_steps)))
    return _sorted_visits(visits)
