"""Structured mobility patterns beyond the random walk and the sparse
Foursquare-style trace: visit-log generators for three scenario families the
paper's framing (opportunistic encounters with fixed smart spaces) suggests
but does not simulate.

All generators return the same visit format as ``synth_foursquare_trace`` —
``[n_visits, 4] int64`` rows of ``(user, place, t_in, t_out)`` sorted by
``t_in`` — so ``trace_to_colocation`` expands any of them into the ``[T, M]``
tensors the scan engine consumes.

- ``commuter_trace``     — home/work oscillation on a daily period: long
  dwells at two anchor places per user, commute gaps in between. Dense,
  highly periodic co-location (the easiest condition for ML Mule).
- ``shift_worker_trace`` — crews partition the day into shifts; each crew
  occupies its workplace only during its window and rotates workplaces
  daily, so snapshots hop between places through shift hand-offs.
- ``event_crowd_trace``  — sparse background visits plus scheduled events
  that pull a large user fraction into one venue simultaneously: bursts of
  many concurrent deliveries stress the freshness filter and aggregation.
- ``multi_area_trace``   — N near-isolated cities of 4 spaces each with
  rare cross-area travelers (generalizes the paper's 2-area layout).

Churn masks
-----------
Real deployments also have devices that join, leave, and sleep mid-run.
The ``*_mask`` generators below produce the ``[T, M]`` bool activity masks
the scan engine threads through every path (``colocation["active"]``):

- ``markov_churn_mask``  — each device is an independent two-state
  (on/off) Markov chain: FedAvg-style random partial participation with
  temporally correlated sessions rather than i.i.d. per-step sampling.
- ``flash_churn_mask``   — a small always-on core plus scheduled flash
  windows where most devices join at once and mass-exit at the end — the
  availability profile of ``event_crowd_trace``.
- ``duty_cycle_mask``    — periodic on/off duty cycles with per-device
  phase jitter (commuters whose devices sleep off-shift).

Every generator is deterministic per seed and guarantees at least one
active mule per step (the engine's aggregation is well-defined either
way, but an all-off step would make a replay trivially dead).
"""
from __future__ import annotations

import numpy as np


def _ensure_one_active(mask: np.ndarray) -> np.ndarray:
    """Force >= 1 active mule per step (deterministic: rotate over mules)."""
    dead = ~mask.any(axis=1)
    if dead.any():
        t = np.nonzero(dead)[0]
        mask[t, t % mask.shape[1]] = True
    return mask


def markov_churn_mask(seed: int, n_steps: int, n_mules: int,
                      p_leave: float = 0.03, p_join: float = 0.12,
                      p_init: float = 0.8) -> np.ndarray:
    """Independent on/off Markov chain per device -> [T, M] bool.

    An active device goes to sleep with ``p_leave`` per step; a sleeping
    one wakes with ``p_join`` (stationary activity ~ p_join / (p_join +
    p_leave)). Sessions are geometrically distributed, matching the
    "devices join and leave mid-run" regime rather than per-step coin
    flips.
    """
    rng = np.random.default_rng(seed)
    mask = np.zeros((n_steps, n_mules), bool)
    state = rng.random(n_mules) < p_init
    for t in range(n_steps):
        mask[t] = state
        flip = rng.random(n_mules)
        state = np.where(state, flip >= p_leave, flip < p_join)
    return _ensure_one_active(mask)


def flash_churn_mask(seed: int, n_steps: int, n_mules: int,
                     n_flashes: int = 4, flash_len: int = 40,
                     join_frac: float = 0.9,
                     base_frac: float = 0.25) -> np.ndarray:
    """Flash joins / mass exits -> [T, M] bool.

    A ``base_frac`` core of devices stays on throughout; at each of
    ``n_flashes`` evenly spaced windows a ``join_frac`` sample of the
    population switches on (staggered arrivals over the first few steps)
    and everyone outside the core mass-exits when the window closes —
    the event-crowd availability profile.
    """
    rng = np.random.default_rng(seed)
    core = rng.random(n_mules) < base_frac
    if not core.any():
        core[int(rng.integers(0, n_mules))] = True
    mask = np.tile(core, (n_steps, 1))
    gap = max(n_steps // max(n_flashes, 1), flash_len + 1)
    for e in range(n_flashes):
        t0 = min(e * gap + int(rng.integers(0, max(gap - flash_len, 1))),
                 max(n_steps - flash_len, 0))
        joiners = rng.random(n_mules) < join_frac
        for u in np.nonzero(joiners)[0]:
            off = int(rng.integers(0, 5))          # staggered arrivals
            mask[t0 + off: t0 + flash_len, u] = True   # mass exit at close
    return _ensure_one_active(mask)


def duty_cycle_mask(seed: int, n_steps: int, n_mules: int,
                    period: int = 120, on_frac: float = 0.55,
                    jitter: int = 15) -> np.ndarray:
    """Periodic per-device duty cycle -> [T, M] bool.

    Device ``m`` is on for ``on_frac * period`` steps of every period,
    phase-shifted by a per-device jitter — commuter devices that sleep
    off-shift, with staggered shift starts.
    """
    rng = np.random.default_rng(seed)
    phase = rng.integers(0, max(jitter, 1) + 1, n_mules)
    on_len = max(int(on_frac * period), 1)
    t = np.arange(n_steps)[:, None]
    mask = ((t + phase[None, :]) % period) < on_len
    return _ensure_one_active(mask)


def _sorted_visits(visits) -> np.ndarray:
    if not visits:
        return np.zeros((0, 4), np.int64)
    arr = np.array(visits, np.int64)
    return arr[np.argsort(arr[:, 2], kind="stable")]


def multi_area_trace(seed: int, n_users: int = 30, n_places: int = 12,
                     n_steps: int = 2000, n_areas: int = 3,
                     p_travel: float = 0.01, min_visits: int = 6,
                     max_visits: int = 18) -> np.ndarray:
    """N near-isolated cities (paper Sec 4.1 generalized past 2 areas).

    Places split into ``n_areas`` contiguous blocks of ``n_places //
    n_areas`` spaces (area = place // block, matching ``trace_colocation``'s
    area derivation). Each user lives in one home area and draws
    foursquare-style visits from it; with probability ``p_travel`` a visit
    crosses into another city — the paper's rare inter-area traveler
    (0.715% in the Foursquare data).
    """
    if n_places != 4 * n_areas:
        raise ValueError(
            f"n_places={n_places} must be 4 * n_areas={n_areas}: the "
            "colocation expansion derives area = place // 4 and space = "
            "place % 4 (4 spaces per area throughout the harness)")
    rng = np.random.default_rng(seed)
    block = n_places // n_areas
    home = rng.integers(0, n_areas, n_users)
    visits = []
    for u in range(n_users):
        t = int(rng.integers(0, max(n_steps // 8, 1)))
        for _ in range(int(rng.integers(min_visits, max_visits + 1))):
            area = int(home[u])
            if rng.random() < p_travel:
                area = int(rng.integers(0, n_areas))
            place = area * block + int(rng.integers(0, block))
            dwell = int(rng.integers(6, 30))
            if t + dwell >= n_steps:
                break
            visits.append((u, place, t, t + dwell))
            t += dwell + int(rng.integers(5, 40))
    return _sorted_visits(visits)


def commuter_trace(seed: int, n_users: int = 20, n_places: int = 8,
                   n_steps: int = 2000, period: int = 200,
                   work_frac: float = 0.45, commute: int = 5,
                   jitter: int = 8) -> np.ndarray:
    """Daily home->work->home cycle per user.

    Each user gets a home and a distinct work place; every `period` steps it
    dwells at home, commutes (`commute` steps off-grid), works for
    ``work_frac * period`` steps (start jittered per user/day), and returns
    home. Produces long dwells, so nearly every visit completes exchanges.
    """
    rng = np.random.default_rng(seed)
    home = rng.integers(0, n_places, n_users)
    work = (home + rng.integers(1, n_places, n_users)) % n_places
    work_len = max(int(work_frac * period), 1)
    visits = []
    for u in range(n_users):
        for day in range(max(n_steps // period, 1)):
            base = day * period
            w0 = base + commute + int(rng.integers(0, jitter + 1))
            w1 = w0 + work_len
            h1 = min(base + period, n_steps)
            if base < w0 - commute:
                visits.append((u, home[u], base, min(w0 - commute, n_steps)))
            if w0 < n_steps:
                visits.append((u, work[u], w0, min(w1, n_steps)))
            if w1 + commute < h1:
                visits.append((u, home[u], w1 + commute, h1))
    return _sorted_visits(visits)


def shift_worker_trace(seed: int, n_users: int = 24, n_places: int = 8,
                       n_steps: int = 2000, n_shifts: int = 3,
                       period: int = 240, jitter: int = 6) -> np.ndarray:
    """Round-the-clock crews: user u works shift ``u % n_shifts``.

    A day of `period` steps splits into `n_shifts` equal windows; crew s is
    at its workplace only during window s and rotates workplace daily
    (``(crew_base + day) % n_places``), so fixed devices see a fresh crew
    every window and models relay across places through the rotation.
    """
    rng = np.random.default_rng(seed)
    shift_of = np.arange(n_users) % n_shifts
    crew_base = rng.integers(0, n_places, n_shifts)
    win = period // n_shifts
    visits = []
    for u in range(n_users):
        s = shift_of[u]
        for day in range(max(n_steps // period, 1)):
            t0 = day * period + s * win + int(rng.integers(0, jitter + 1))
            t1 = min(day * period + (s + 1) * win, n_steps)
            place = (crew_base[s] + day) % n_places
            if t0 < t1:
                visits.append((u, place, t0, t1))
    return _sorted_visits(visits)


def event_crowd_trace(seed: int, n_users: int = 30, n_places: int = 8,
                      n_steps: int = 2000, n_events: int = 6,
                      event_len: int = 60, attend: float = 0.7,
                      background_visits: int = 3) -> np.ndarray:
    """Sparse background check-ins punctuated by mass events.

    Events are evenly spaced (start jittered); each picks one venue and an
    ``attend`` fraction of users who all dwell there for ``event_len`` steps
    — many simultaneous deliveries to a single fixed device.
    """
    rng = np.random.default_rng(seed)
    visits = []
    for u in range(n_users):                       # thin background traffic
        for _ in range(int(rng.integers(1, background_visits + 1))):
            t0 = int(rng.integers(0, max(n_steps - 10, 1)))
            dwell = int(rng.integers(4, 20))
            visits.append((u, int(rng.integers(0, n_places)), t0,
                           min(t0 + dwell, n_steps)))
    gap = max(n_steps // max(n_events, 1), event_len + 1)
    for e in range(n_events):
        t0 = min(e * gap + int(rng.integers(0, max(gap - event_len, 1))),
                 max(n_steps - event_len, 0))
        venue = int(rng.integers(0, n_places))
        goers = rng.random(n_users) < attend
        for u in np.nonzero(goers)[0]:
            off = int(rng.integers(0, 5))          # staggered arrivals
            visits.append((int(u), venue, t0 + off,
                           min(t0 + event_len, n_steps)))
    return _sorted_visits(visits)
