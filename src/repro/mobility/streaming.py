"""Streaming colocation generators: the schedule never materializes [T, M].

The scan engine historically replayed a *precomputed* ``[T, M]`` colocation
schedule — at M=10^6 that tensor alone dwarfs the population state. The
generators here emit the schedule chunk by chunk *inside* the compiled
replay (``repro.scenarios.run_population_streamed``), so host and device
memory for the schedule is O(chunk * M) plus O(M * segments) of compact
per-mule parameters, never O(T * M).

The generator contract
----------------------
A chunk generator is an object with

- ``n_mules``/``n_steps``  — population size and nominal horizon;
- ``arrays()``             — a pytree of device arrays (the compact
  schedule / per-mule parameters). Passed to the compiled chunk program as
  *traced inputs*, so two generators with the same shapes share one
  executable; under ``shard_map`` each leaf shards per ``specs()``, so a
  shard's expansion touches only its own mule columns;
- ``specs(axis)``          — matching pytree of ``PartitionSpec`` for the
  distributed engine (mule-leading leaves shard, the rest replicate);
- ``static_token()``       — hashable tuple of everything *baked into the
  trace* (periods, cadences, flags). Joins the engine's jit-cache key
  together with the array signature — deliberately **excluding** the
  horizon ``n_steps``, so replays of different lengths reuse one compiled
  chunk program;
- ``generate_chunk(key, t0, chunk_len)`` — the hot path: pure ``jnp``
  math (traceable, no host NumPy), returning ``{"fixed_id": [c, n] int32,
  "exchange": [c, n] bool, "pos": [c, n, 2] f32, "area": [n] int32 (or
  [c, n] when the schedule's areas move — migratory traces),
  "active": [c, n] bool}`` for global steps ``t0 .. t0+chunk_len``.
  ``key`` is an optional override PRNG key; the builders below bake their
  seed at build time and ignore it, which is what makes a streamed replay
  and a materialized reference of the same generator bitwise-identical.
  ``expand(arrays, key, t0, chunk_len)`` is the same computation with the
  array pytree passed explicitly (what the engine traces).

Two families:

- :func:`compact_colocation` losslessly compacts ANY materialized
  colocation dict into per-mule run-length segments and expands them
  on-device — bitwise-equal to the host tensors by construction, chunk
  boundaries included. This is how every *registered* scenario streams.
  Exchange flags are re-derived closed-form from run starts and the dwell
  cadence whenever that reproduces the input exactly (it does for every
  trace/walk scenario — they are all dwell-cadence schedules), falling
  back to a verbatim RLE of the exchange columns otherwise.
- :func:`commuter_stream` is fully procedural: O(M) per-mule parameters
  drawn once with ``jax.random`` at build time, closed-form schedule per
  ``(t, mule)`` in the hot path — the generator the M=10^5..10^6 scale
  sweep (``benchmarks/engine_micro.run_scale_bench``) runs, since its
  memory is independent of T entirely.

``materialize_generator`` turns any generator back into the classic
numpy colocation dict — the O(T * M) parity reference, playing the role
``run_population_loop`` plays for the scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.mobility.trace import dwell_exchange_flags

# padding sentinel for RLE start times: larger than any reachable step but
# safely below int32 overflow when compared against t0 + chunk offsets
_PAD_T = np.iinfo(np.int32).max // 2


def _rle_columns(arr: np.ndarray, pad_val) -> Tuple[np.ndarray, np.ndarray]:
    """Per-column run-length encoding of a ``[T, M]`` array.

    Returns ``(starts [M, S] int32, values [M, S])`` where column ``m``'s
    runs are ``values[m, i]`` from step ``starts[m, i]`` (inclusive) to the
    next start; ``S`` is the max run count over columns and shorter columns
    pad with ``(_PAD_T, pad_val)`` entries that no in-range step selects.
    """
    t_len, m = arr.shape
    change = np.ones((t_len, m), bool)
    change[1:] = arr[1:] != arr[:-1]
    counts = change.sum(axis=0)
    s = int(counts.max()) if m else 1
    cols, rows = np.nonzero(change.T)          # sorted by column, then step
    slot = np.arange(len(rows)) - np.repeat(np.cumsum(counts) - counts,
                                            counts)
    starts = np.full((m, s), _PAD_T, np.int32)
    values = np.full((m, s), pad_val, arr.dtype)
    starts[cols, slot] = rows
    values[cols, slot] = arr[rows, cols]
    return starts, values


def _expand_rle(starts: jnp.ndarray, values: jnp.ndarray,
                ts: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Evaluate per-mule step functions at steps ``ts``.

    ``starts``/``values``: ``[n, S]``; ``ts``: ``[c]``. Returns
    ``(vals [c, n], run_start [c, n])`` — the run value at each step and
    the step its run began (feeds the closed-form dwell cadence).
    """
    idx = jax.vmap(
        lambda s: jnp.searchsorted(s, ts, side="right") - 1)(starts)  # [n, c]
    vals = jnp.take_along_axis(values, idx, axis=1)
    run_start = jnp.take_along_axis(starts, idx, axis=1)
    return vals.T, run_start.T


class CompactColocation:
    """Exact compact form of a materialized colocation dict.

    Per-mule RLE segments for ``fixed_id`` (and, when present, the churn
    mask), closed-form dwell-cadence exchange (or RLE fallback), zeros or
    dense pass-through for ``pos``. ``generate_chunk`` reproduces the
    source tensors bitwise at any chunk boundary: integer/boolean RLE
    expansion is exact, and the cadence formula is only used when build-time
    verification proved it reproduces the input exchange exactly.
    """

    def __init__(self, n_mules: int, n_steps: int, arrays: Dict[str, Any],
                 *, cadence_scalar: Optional[int], has_active: bool,
                 has_exchange_rle: bool, has_dense_pos: bool,
                 has_area_rle: bool = False, max_area: int = 0):
        self.n_mules = int(n_mules)
        self.n_steps = int(n_steps)
        self.max_area = int(max_area)
        self._arrays = arrays
        self._cadence_scalar = cadence_scalar
        self._has_active = has_active
        self._has_exchange_rle = has_exchange_rle
        self._has_dense_pos = has_dense_pos
        self._has_area_rle = has_area_rle

    def arrays(self) -> Dict[str, Any]:
        return self._arrays

    def specs(self, axis: str):
        """PartitionSpecs per array leaf: mule-leading leaves shard."""
        from jax.sharding import PartitionSpec as P
        per_leaf = {
            "fid_starts": P(axis, None), "fid_vals": P(axis, None),
            "act_starts": P(axis, None), "act_vals": P(axis, None),
            "exc_starts": P(axis, None), "exc_vals": P(axis, None),
            "area_starts": P(axis, None), "area_vals": P(axis, None),
            "area": P(axis), "cadence": P(),
            "pos": P(None, axis, None),
        }
        return {k: per_leaf[k] for k in self._arrays}

    def static_token(self) -> Tuple:
        return ("compact", self._cadence_scalar, self._has_active,
                self._has_exchange_rle, self._has_dense_pos,
                self._has_area_rle)

    def schedule_bytes(self) -> int:
        """Bytes of compact schedule resident on device (O(M * segments))."""
        return sum(int(np.asarray(l).nbytes)
                   for l in jax.tree.leaves(self._arrays))

    def expand(self, arrays: Dict[str, Any], key, t0,
               chunk_len: int) -> Dict[str, Any]:
        del key                                  # deterministic from build
        ts = jnp.asarray(t0, jnp.int32) + jnp.arange(chunk_len, dtype=jnp.int32)
        fid, run_start = _expand_rle(arrays["fid_starts"],
                                     arrays["fid_vals"], ts)
        present = fid >= 0
        if self._has_exchange_rle:
            exch, _ = _expand_rle(arrays["exc_starts"], arrays["exc_vals"],
                                  ts)
        else:
            dwell = ts[:, None] - run_start + 1
            if self._cadence_scalar is not None:
                steps = jnp.int32(self._cadence_scalar)
            else:
                steps = arrays["cadence"][jnp.maximum(fid, 0)]
            exch = present & (dwell % steps == 0)
        if self._has_active:
            act, _ = _expand_rle(arrays["act_starts"], arrays["act_vals"],
                                 ts)
        else:
            act = jnp.ones(fid.shape, bool)
        n = fid.shape[1]
        if self._has_dense_pos:
            pos = jax.lax.dynamic_slice(
                arrays["pos"], (ts[0], 0, 0),
                (chunk_len, n, 2))
        else:
            pos = jnp.zeros((chunk_len, n, 2), jnp.float32)
        if self._has_area_rle:
            area, _ = _expand_rle(arrays["area_starts"],
                                  arrays["area_vals"], ts)
        else:
            area = arrays["area"]
        return {"fixed_id": fid, "exchange": exch, "pos": pos,
                "area": area, "active": act}

    def generate_chunk(self, key, t0, chunk_len: int) -> Dict[str, Any]:
        return self.expand(self._arrays, key, t0, chunk_len)


def compact_colocation(colocation: Dict[str, Any],
                       cadence=3) -> CompactColocation:
    """Compact a materialized colocation dict into a streaming generator.

    ``cadence`` is the dwell exchange tempo the schedule was built with (a
    scalar or the per-place array of a ``SpaceSpec`` scenario). The
    closed-form cadence expansion is *verified* against the input exchange
    tensor here on the host; a schedule whose exchange flags are not
    dwell-cadence-shaped (or whose cadence was guessed wrong) falls back to
    an exact RLE of the exchange columns — less compact, never wrong.
    """
    fid = np.asarray(colocation["fixed_id"], np.int32)
    exch = np.asarray(colocation["exchange"], bool)
    n_steps, n_mules = fid.shape
    arrays: Dict[str, Any] = {}

    fs, fv = _rle_columns(fid, np.int32(-1))
    arrays["fid_starts"] = jnp.asarray(fs)
    arrays["fid_vals"] = jnp.asarray(fv)

    cadence_scalar: Optional[int] = None
    has_exchange_rle = not np.array_equal(
        dwell_exchange_flags(fid, cadence), exch)
    if has_exchange_rle:
        es, ev = _rle_columns(exch, False)
        arrays["exc_starts"] = jnp.asarray(es)
        arrays["exc_vals"] = jnp.asarray(ev)
    elif np.ndim(cadence) == 0:
        cadence_scalar = int(cadence)
    else:
        arrays["cadence"] = jnp.asarray(np.asarray(cadence), jnp.int32)

    active = colocation.get("active")
    has_active = active is not None
    if has_active:
        as_, av = _rle_columns(np.asarray(active, bool), False)
        arrays["act_starts"] = jnp.asarray(as_)
        arrays["act_vals"] = jnp.asarray(av)

    pos = colocation.get("pos")
    has_dense_pos = pos is not None and np.asarray(pos).any()
    if has_dense_pos:
        arrays["pos"] = jnp.asarray(np.asarray(pos), jnp.float32)

    area = colocation.get("area")
    area = (np.zeros((n_mules,), np.int32) if area is None
            else np.asarray(area, np.int32))
    has_area_rle = area.ndim == 2
    if has_area_rle:
        ars, arv = _rle_columns(area, np.int32(0))
        arrays["area_starts"] = jnp.asarray(ars)
        arrays["area_vals"] = jnp.asarray(arv)
    else:
        arrays["area"] = jnp.asarray(area)

    return CompactColocation(n_mules, n_steps, arrays,
                             cadence_scalar=cadence_scalar,
                             has_active=has_active,
                             has_exchange_rle=has_exchange_rle,
                             has_dense_pos=has_dense_pos,
                             has_area_rle=has_area_rle,
                             max_area=int(area.max(initial=0)))


class CommuterStream:
    """Procedural counter-keyed commuter schedule: O(M) memory, any T.

    Per-mule home/work places, jitter phase, and (odd) day stride are drawn
    once at build time with pure ``jax.random``; the hot path derives the
    step's place from ``(t, mule)`` with integer math only. Day ``d`` of
    mule ``m`` looks like::

        [home   j) [commute) [work  work_len) [commute) [home   period)

    with ``j = (phase + d * stride) % (jitter + 1)`` — a per-(mule, day)
    jitter that is layout-independent, so a shard expanding only its own
    columns produces exactly the single-host columns. Exchange flags are
    the standard dwell cadence; an evening-home run that touches midnight
    *continues* into the next morning (the run start reaches back across
    the day boundary), so the flags agree bitwise with
    ``dwell_exchange_flags`` over the materialized grid — compacting a
    materialization of this generator round-trips exactly.

    Optional duty-cycle churn (``duty_period > 0``): mule ``m`` is active
    while ``(t + aphase[m]) % duty_period < duty_on``, with mule
    ``t % n_mules`` forced on so no step goes fully dark.
    """

    def __init__(self, seed: int, n_mules: int, n_steps: int, *,
                 n_places: int = 8, period: int = 192,
                 work_frac: float = 0.45, commute: int = 6, jitter: int = 8,
                 exchange_steps: int = 3, duty_period: int = 0,
                 duty_on_frac: float = 0.6):
        work_len = max(int(work_frac * period), 1)
        if jitter + 2 * commute + work_len >= period:
            raise ValueError(
                f"period={period} too short for jitter={jitter} + "
                f"2*commute={2 * commute} + work_len={work_len}")
        self.n_mules = int(n_mules)
        self.n_steps = int(n_steps)
        self.n_places = int(n_places)
        self.period = int(period)
        self.work_len = work_len
        self.commute = int(commute)
        self.jitter = int(jitter)
        self.exchange_steps = int(exchange_steps)
        self.max_area = (int(n_places) - 1) // 4
        self.duty_period = int(duty_period)
        self.duty_on = max(int(duty_on_frac * duty_period), 1) \
            if duty_period else 0

        kh, kw, kp, ks, ka = jax.random.split(jax.random.PRNGKey(seed), 5)
        m = self.n_mules
        home = jax.random.randint(kh, (m,), 0, n_places, jnp.int32)
        work = (home + jax.random.randint(kw, (m,), 1, n_places,
                                          jnp.int32)) % n_places
        self._arrays = {
            "home": home,
            "work": work,
            "phase": jax.random.randint(kp, (m,), 0, self.jitter + 1,
                                        jnp.int32),
            "stride": 2 * jax.random.randint(ks, (m,), 0, 1 << 15,
                                             jnp.int32) + 1,
            "ids": jnp.arange(m, dtype=jnp.int32),
        }
        if duty_period:
            self._arrays["aphase"] = jax.random.randint(
                ka, (m,), 0, duty_period, jnp.int32)

    def arrays(self) -> Dict[str, Any]:
        return self._arrays

    def specs(self, axis: str):
        from jax.sharding import PartitionSpec as P
        return {k: P(axis) for k in self._arrays}

    def static_token(self) -> Tuple:
        return ("commuter_stream", self.n_mules, self.n_places, self.period,
                self.work_len, self.commute, self.jitter,
                self.exchange_steps, self.duty_period, self.duty_on)

    def schedule_bytes(self) -> int:
        return sum(int(np.asarray(l).nbytes)
                   for l in jax.tree.leaves(self._arrays))

    def _day_jitter(self, day: jnp.ndarray, phase: jnp.ndarray,
                    stride: jnp.ndarray) -> jnp.ndarray:
        return (phase[None, :] + day[:, None] * stride[None, :]) \
            % (self.jitter + 1)

    def expand(self, arrays: Dict[str, Any], key, t0,
               chunk_len: int) -> Dict[str, Any]:
        del key                                  # deterministic from build
        p = self.period
        ts = jnp.asarray(t0, jnp.int32) + jnp.arange(chunk_len, dtype=jnp.int32)
        day, w = ts // p, ts % p                            # [c]
        phase, stride = arrays["phase"], arrays["stride"]
        j = self._day_jitter(day, phase, stride)            # [c, n]
        w0 = j + self.commute                               # work start
        w1 = w0 + self.work_len
        we = w1 + self.commute                              # evening start
        wb = w[:, None]
        morning, at_work, evening = wb < j, (wb >= w0) & (wb < w1), wb >= we
        fid = jnp.where(morning | evening, arrays["home"][None, :],
                        jnp.where(at_work, arrays["work"][None, :], -1))

        # run starts (absolute steps). The morning-home run continues the
        # previous evening's run when that evening existed (we < period),
        # matching host dwell semantics over the materialized grid.
        j_prev = self._day_jitter(day - 1, phase, stride)
        we_prev = j_prev + 2 * self.commute + self.work_len
        day_base = (day * p)[:, None]
        morning_start = jnp.where(
            (day[:, None] > 0) & (we_prev < p),
            day_base - p + we_prev, day_base)
        run_start = jnp.where(morning, morning_start,
                              jnp.where(at_work, day_base + w0,
                                        day_base + we))
        dwell = ts[:, None] - run_start + 1
        exch = (fid >= 0) & (dwell % self.exchange_steps == 0)

        if self.duty_period:
            act = ((ts[:, None] + arrays["aphase"][None, :])
                   % self.duty_period) < self.duty_on
            act = act | (arrays["ids"][None, :] == ts[:, None] % self.n_mules)
        else:
            act = jnp.ones(fid.shape, bool)
        pos = jnp.zeros((chunk_len, fid.shape[1], 2), jnp.float32)
        return {"fixed_id": fid.astype(jnp.int32), "exchange": exch,
                "pos": pos, "area": arrays["home"] // 4, "active": act}

    def generate_chunk(self, key, t0, chunk_len: int) -> Dict[str, Any]:
        return self.expand(self._arrays, key, t0, chunk_len)

    def init_fields(self) -> Dict[str, np.ndarray]:
        """init_space/init_area for the data partitioners (home-derived)."""
        home = np.asarray(self._arrays["home"])
        return {"init_space": (home % 4).astype(np.int64),
                "init_area": (home // 4).astype(np.int64)}


def commuter_stream(seed: int, n_mules: int, n_steps: int,
                    **kw) -> CommuterStream:
    """Build the procedural commuter generator (see :class:`CommuterStream`)."""
    return CommuterStream(seed, n_mules, n_steps, **kw)


def reorder_generator_arrays(generator, arrays: Dict[str, Any],
                             order) -> Dict[str, Any]:
    """Permute a generator's in-flight mule columns into a new bucket order.

    Leaves whose ``specs()`` entry shards over the mule axis are gathered
    along that axis with ``order`` (entry ``p`` names the source column for
    the mule now in slot ``p``); replicated leaves pass through untouched.
    This is what the streamed engine's mid-run re-bucketing applies to
    ``generator.arrays()`` at a swap, so every later ``expand`` emits its
    columns in the post-swap layout. The gather runs jitted so arrays
    placed across a multi-process mesh reorder in place (an eager gather
    rejects them); single-process results are bitwise unchanged.
    """
    order = np.asarray(order)
    sentinel = "_mule_"
    specs = generator.specs(sentinel)

    def one(spec, leaf):
        axes = tuple(spec)
        if sentinel in axes:
            return _axis_gather(leaf, order, axes.index(sentinel))
        return leaf

    return {k: one(specs[k], v) for k, v in arrays.items()}


@functools.partial(jax.jit, static_argnums=2)
def _axis_gather(leaf, order, axis):
    return jnp.take(leaf, jnp.asarray(order), axis=axis)


def materialize_generator(gen, n_steps: Optional[int] = None,
                          chunk_len: int = 256) -> Dict[str, np.ndarray]:
    """Expand a chunk generator into the classic numpy colocation dict.

    The O(T * M) reference path: streamed replay must be bitwise-equal to
    ``run_population`` over this dict (the scale bench asserts it per M).
    Includes ``init_space``/``init_area`` when the generator provides them.
    """
    n_steps = int(gen.n_steps if n_steps is None else n_steps)
    chunks = []
    for t0 in range(0, n_steps, chunk_len):
        c = gen.generate_chunk(None, t0, min(chunk_len, n_steps - t0))
        chunks.append({k: np.asarray(v) for k, v in c.items()})
    co = {k: np.concatenate([c[k] for c in chunks], axis=0)
          for k in ("fixed_id", "exchange", "pos", "active")}
    if chunks and chunks[0]["area"].ndim == 2:
        co["area"] = np.concatenate([c["area"] for c in chunks], axis=0)
    else:
        co["area"] = chunks[0]["area"] if chunks else np.zeros(
            (gen.n_mules,), np.int32)
    if hasattr(gen, "init_fields"):
        co.update(gen.init_fields())
    return co
