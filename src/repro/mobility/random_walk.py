"""Random-walk mobility with crossing probability P_cross (paper Sec 4.1).

Geometry: ``n_areas`` isolated unit squares. Each area holds four spaces —
the corner cells of side ``space_size`` — and an empty central corridor (the
paper's Fig. 4 layout). One fixed device sits in each space.

Dynamics per step (vectorized over mules, jittable):
- gaussian step proposal, reflected at the area walls;
- if the proposal exits the mule's current space, it is accepted with
  probability ``p_cross`` and otherwise reflected back into the space
  (``p_cross = 0`` -> devices never leave; higher values -> more inter-space
  movement), matching the paper's "probability of leaving the current space".
- areas are fully isolated (the paper observed only ~0.7% cross-city travel
  and simulated none).

``space_of`` maps positions to space ids 0..3 or -1 (corridor). Global fixed
device id = area * 4 + space.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MobilityConfig:
    n_mules: int = 20
    n_areas: int = 2
    p_cross: float = 0.1
    step_sigma: float = 0.08
    space_size: float = 0.42     # corner cell side; corridor is the rest
    exchange_steps: int = 3      # time steps to complete one model transfer


def space_of(pos: jnp.ndarray, space_size: float) -> jnp.ndarray:
    """pos: [..., 2] in [0,1]^2 -> space id 0..3 or -1 (corridor)."""
    x, y = pos[..., 0], pos[..., 1]
    lo = space_size
    hi = 1.0 - space_size
    in_left = x < lo
    in_right = x > hi
    in_bot = y < lo
    in_top = y > hi
    sid = jnp.where(in_left & in_bot, 0,
          jnp.where(in_right & in_bot, 1,
          jnp.where(in_left & in_top, 2,
          jnp.where(in_right & in_top, 3, -1))))
    return sid


def _space_bounds(sid, space_size):
    """Bounding box (lo, hi) per axis for a space id (when sid >= 0)."""
    right = (sid == 1) | (sid == 3)
    top = sid >= 2
    lo_x = jnp.where(right, 1.0 - space_size, 0.0)
    hi_x = jnp.where(right, 1.0, space_size)
    lo_y = jnp.where(top, 1.0 - space_size, 0.0)
    hi_y = jnp.where(top, 1.0, space_size)
    return lo_x, hi_x, lo_y, hi_y


def init_mobility(key, cfg: MobilityConfig):
    """Mules start uniformly inside random spaces of their (fixed) area."""
    k1, k2, k3 = jax.random.split(key, 3)
    m = cfg.n_mules
    area = jnp.arange(m) % cfg.n_areas                      # balanced assignment
    sid = jax.random.randint(k1, (m,), 0, 4)
    u = jax.random.uniform(k2, (m, 2)) * cfg.space_size
    lo_x, _, lo_y, _ = _space_bounds(sid, cfg.space_size)
    pos = jnp.stack([lo_x + u[:, 0], lo_y + u[:, 1]], axis=-1)
    return {
        "pos": pos,                                          # [M, 2]
        "area": area.astype(jnp.int32),                      # [M]
        "dwell": jnp.zeros((m,), jnp.int32),                 # consecutive steps in space
        "key": k3,
    }


def mobility_step(state, cfg: MobilityConfig):
    """One time step. Returns (new_state, info dict)."""
    key, k_step, k_cross = jax.random.split(state["key"], 3)
    pos = state["pos"]
    m = pos.shape[0]
    cur_sid = space_of(pos, cfg.space_size)

    prop = pos + cfg.step_sigma * jax.random.normal(k_step, (m, 2))
    prop = jnp.clip(prop, 0.0, 1.0)                          # area walls
    prop_sid = space_of(prop, cfg.space_size)

    exits = (cur_sid >= 0) & (prop_sid != cur_sid)
    allow = jax.random.uniform(k_cross, (m,)) < cfg.p_cross
    # reflected-back position: clamp into current space bounds (eps keeps the
    # point strictly inside — space membership uses strict inequalities)
    eps = 1e-4
    lo_x, hi_x, lo_y, hi_y = _space_bounds(cur_sid, cfg.space_size)
    clamped = jnp.stack(
        [jnp.clip(prop[:, 0], lo_x + eps * (lo_x > 0), hi_x - eps * (hi_x < 1)),
         jnp.clip(prop[:, 1], lo_y + eps * (lo_y > 0), hi_y - eps * (hi_y < 1))],
        axis=-1)
    new_pos = jnp.where((exits & ~allow)[:, None], clamped, prop)
    new_sid = space_of(new_pos, cfg.space_size)

    same = (new_sid == cur_sid) & (new_sid >= 0)
    dwell = jnp.where(same, state["dwell"] + 1, jnp.where(new_sid >= 0, 1, 0))

    # an exchange completes every `exchange_steps` consecutive steps in a space
    exchange = (dwell > 0) & (dwell % cfg.exchange_steps == 0)
    fixed_id = jnp.where(new_sid >= 0, state["area"] * 4 + new_sid, -1)

    new_state = {"pos": new_pos, "area": state["area"], "dwell": dwell, "key": key}
    info = {"space": new_sid, "fixed_id": fixed_id.astype(jnp.int32),
            "exchange": exchange, "pos": new_pos}
    return new_state, info


def simulate_trajectories(key, cfg: MobilityConfig, n_steps: int):
    """Unrolled trajectory (for analysis/benchmarks): dict of [T, M] arrays."""
    state = init_mobility(key, cfg)

    def step(s, _):
        s, info = mobility_step(s, cfg)
        return s, info

    _, infos = jax.lax.scan(step, state, None, length=n_steps)
    return infos
